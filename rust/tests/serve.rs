//! Integration tests for the serving layer: an in-process daemon on a
//! temp socket, driven by real protocol clients.
//!
//! The headline assertions, per the subsystem's contract:
//! - concurrent daemon responses are **byte-identical** to each other
//!   and carry exactly the edges a direct in-process
//!   `Prepared::recover` produces,
//! - cache hit/miss accounting is exact and LRU eviction follows
//!   recency order at capacity two,
//! - past the admission cap, requests are rejected with the typed
//!   structured `overloaded` error and succeed once load drains,
//! - failures degrade gracefully: a bad-α recover and a blown deadline
//!   poison neither the cache entry nor the daemon,
//! - the bombard replay completes a mixed load with zero failures.
//!
//! Tests spawn raw `std::thread` clients deliberately — the audit's
//! thread-outside-pool rule exempts tests, and real clients live outside
//! the daemon's pool.

use pdgrass::config::ServeConfig;
use pdgrass::serve::json::{self, Value};
use pdgrass::serve::{bombard, BombardConfig, Client, Server};
use pdgrass::session::{RecoverOpts, Sparsify};

const SCALE: f64 = 0.02;

/// Unique-per-test socket path (under `sun_path`'s ~100-byte limit).
fn sock(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pdg-{}-{tag}.sock", std::process::id()))
}

/// Start a daemon on a fresh socket with quiet logging, then let the
/// test tweak the config.
fn start(tag: &str, tweak: impl FnOnce(&mut ServeConfig)) -> Server {
    let mut cfg = ServeConfig { socket: sock(tag), log: "off".to_string(), ..Default::default() };
    tweak(&mut cfg);
    let _ = std::fs::remove_file(&cfg.socket);
    Server::start(cfg).expect("daemon must start on a fresh temp socket")
}

fn recover_line(id: u64, name: &str, alpha: f64) -> String {
    format!(
        r#"{{"id":{id},"verb":"recover","graph":{{"name":"{name}","scale":{SCALE}}},"alpha":{alpha}}}"#
    )
}

fn call(server: &Server, line: &str) -> Value {
    let mut client = Client::connect(server.socket()).unwrap();
    let resp = client.call_line(line).unwrap();
    json::parse(&resp).unwrap()
}

#[test]
fn cache_hit_accounting_is_exact() {
    let server = start("hits", |_| {});
    let mut client = Client::connect(server.socket()).unwrap();
    let first = client.call_line(&recover_line(1, "15-M6", 0.05)).unwrap();
    assert!(first.contains(r#""ok":true"#), "{first}");
    // Identical spec again: served from cache, byte-identical except id.
    let second = client.call_line(&recover_line(2, "15-M6", 0.05)).unwrap();
    assert!(second.contains(r#""ok":true"#), "{second}");

    let stats = server.cache().stats();
    assert_eq!(stats.entries, 1);
    assert_eq!(stats.misses, 1, "first request misses");
    assert_eq!(stats.hits, 1, "second request hits the spec memo");
    assert_eq!(stats.evictions, 0);

    // The stats verb reports the same numbers over the wire.
    let v = call(&server, r#"{"id":3,"verb":"stats"}"#);
    let cache = v.get("cache").unwrap();
    assert_eq!(cache.get("hits").unwrap().as_u64(), Some(1));
    assert_eq!(cache.get("misses").unwrap().as_u64(), Some(1));
    assert_eq!(cache.get("entries").unwrap().as_u64(), Some(1));
    assert_eq!(cache.get("resident").unwrap().as_arr().unwrap().len(), 1);

    server.stop();
    server.wait();
}

#[test]
fn lru_eviction_follows_recency_at_capacity_two() {
    let server = start("lru", |cfg| cfg.cache_capacity = 2);
    let mut client = Client::connect(server.socket()).unwrap();
    let fp_of = |resp: &str| {
        json::parse(resp).unwrap().get("fingerprint").unwrap().as_str().unwrap().to_string()
    };
    let a = fp_of(&client.call_line(&recover_line(1, "15-M6", 0.05)).unwrap());
    let _b = fp_of(&client.call_line(&recover_line(2, "07-com-DBLP", 0.05)).unwrap());
    // Touch A so B is least recently used, then add C.
    client.call_line(&recover_line(3, "15-M6", 0.05)).unwrap();
    let c = fp_of(&client.call_line(&recover_line(4, "09-com-Youtube", 0.05)).unwrap());

    let stats = server.cache().stats();
    assert_eq!(stats.entries, 2, "capacity two");
    assert_eq!(stats.evictions, 1, "exactly B was LRU-evicted");
    let resident: Vec<String> = server
        .cache()
        .resident()
        .into_iter()
        .map(|(fp, _)| pdgrass::graph::fingerprint_hex(fp))
        .collect();
    assert!(resident.contains(&a), "A touched, stays");
    assert!(resident.contains(&c), "C just inserted, stays");

    // Fingerprint-addressed request for the evicted B: typed miss.
    let evicted = call(
        &server,
        r#"{"id":5,"verb":"recover","fingerprint":"0x0000000000000001","alpha":0.05}"#,
    );
    assert_eq!(evicted.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(evicted.get("error").unwrap().as_str(), Some("unknown_graph"));

    server.stop();
    server.wait();
}

#[test]
fn concurrent_recovers_are_bitwise_identical_to_direct() {
    let server = start("bitwise", |cfg| cfg.max_in_flight = 8);
    let line = format!(
        r#"{{"id":7,"verb":"recover","graph":{{"name":"15-M6","scale":{SCALE}}},"alpha":0.05,"return_edges":true}}"#
    );
    let path = server.socket().to_path_buf();
    let mut handles = Vec::new();
    for _ in 0..4 {
        let path = path.clone();
        let line = line.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&path).unwrap();
            client.call_line(&line).unwrap()
        }));
    }
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &responses[1..] {
        assert_eq!(r, &responses[0], "concurrent responses must be byte-identical");
    }

    // Ground truth: the same recovery, run directly in-process.
    let prepared = Sparsify::suite("15-M6", SCALE, pdgrass::gen::DEFAULT_SEED)
        .unwrap()
        .prepare()
        .unwrap();
    let direct = prepared.recover(&RecoverOpts::with_threads(0.05, 2)).unwrap();

    let v = json::parse(&responses[0]).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(
        v.get("fingerprint").unwrap().as_str().unwrap(),
        pdgrass::graph::fingerprint_hex(prepared.fingerprint())
    );
    assert_eq!(v.get("recovered").unwrap().as_u64(), Some(direct.edges().len() as u64));
    let served: Vec<u32> = v
        .get("edges")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|e| e.as_u64().unwrap() as u32)
        .collect();
    assert_eq!(served, direct.edges(), "served edges == direct Prepared::recover edges");

    server.stop();
    server.wait();
}

#[test]
fn overloaded_rejection_is_typed_and_drains() {
    let server = start("overload", |cfg| cfg.max_in_flight = 1);
    // Pin the daemon at its cap deterministically.
    let permit = server.admission().try_acquire().unwrap();
    let v = call(&server, &recover_line(1, "15-M6", 0.05));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
    assert_eq!(v.get("in_flight").unwrap().as_u64(), Some(1));
    assert_eq!(v.get("cap").unwrap().as_u64(), Some(1));
    // Control verbs bypass admission even at the cap.
    let stats = call(&server, r#"{"id":2,"verb":"stats"}"#);
    assert_eq!(stats.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(
        stats.get("admission").unwrap().get("rejected").unwrap().as_u64(),
        Some(1)
    );
    // Load drains: the identical request now succeeds.
    drop(permit);
    let v = call(&server, &recover_line(3, "15-M6", 0.05));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{v:?}");

    server.stop();
    server.wait();
}

#[test]
fn failures_degrade_gracefully_without_poisoning() {
    let server = start("graceful", |cfg| cfg.failure_cap = 2);
    let mut client = Client::connect(server.socket()).unwrap();

    // Warm the cache, then fail a recover against it (bad α).
    let ok = client.call_line(&recover_line(1, "15-M6", 0.05)).unwrap();
    assert!(ok.contains(r#""ok":true"#), "{ok}");
    let bad = json::parse(&client.call_line(&recover_line(2, "15-M6", -1.0)).unwrap()).unwrap();
    assert_eq!(bad.get("error").unwrap().as_str(), Some("bad_param"));
    // Neither the entry nor the daemon is poisoned: same spec recovers
    // fine, still from cache.
    let hits_before = server.cache().stats().hits;
    let again = client.call_line(&recover_line(3, "15-M6", 0.05)).unwrap();
    assert!(again.contains(r#""ok":true"#), "{again}");
    assert!(server.cache().stats().hits > hits_before, "entry survived the failed recover");

    // Prepare failures trip the per-spec cap...
    let nope = r#"{"id":4,"verb":"recover","graph":{"name":"no-such-graph"},"alpha":0.05}"#;
    let first = json::parse(&client.call_line(nope).unwrap()).unwrap();
    assert_eq!(first.get("error").unwrap().as_str(), Some("unknown_graph"));
    let second = json::parse(&client.call_line(nope).unwrap()).unwrap();
    assert_eq!(second.get("error").unwrap().as_str(), Some("unknown_graph"));
    let capped = json::parse(&client.call_line(nope).unwrap()).unwrap();
    assert_eq!(capped.get("error").unwrap().as_str(), Some("bad_param"), "{capped:?}");
    assert!(
        capped.get("message").unwrap().as_str().unwrap().contains("evict"),
        "the fast-reject names the reset escape hatch"
    );
    // ...and `evict` resets the cap (back to the real error).
    let ev = json::parse(&client.call_line(r#"{"id":7,"verb":"evict"}"#).unwrap()).unwrap();
    assert_eq!(ev.get("ok").unwrap().as_bool(), Some(true));
    let reset = json::parse(&client.call_line(nope).unwrap()).unwrap();
    assert_eq!(reset.get("error").unwrap().as_str(), Some("unknown_graph"));

    // A malformed line gets a protocol error and keeps the connection.
    let garbage = client.call_line("this is not json").unwrap();
    assert!(garbage.contains(r#""error":"protocol""#), "{garbage}");
    let still_alive = client.call_line(r#"{"id":8,"verb":"stats"}"#).unwrap();
    assert!(still_alive.contains(r#""ok":true"#), "{still_alive}");

    server.stop();
    server.wait();
}

#[test]
fn deadline_exceeded_is_typed_and_the_cache_keeps_the_work() {
    let server = start("deadline", |_| {});
    // A 1 ms deadline cannot cover a cold prepare + PCG solve; the
    // response is a typed deadline_exceeded...
    let line = r#"{"id":1,"verb":"pcg","graph":{"name":"09-com-Youtube","scale":0.05},"alpha":0.05,"deadline_ms":1}"#;
    // In principle a heavily-loaded host could blow the 1 ms deadline at
    // the check *before* the prepare stage, in which case no work was
    // admitted yet — retry until the deadline fires after it.
    for attempt in 0.. {
        let v = call(&server, line);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{v:?}");
        assert_eq!(v.get("error").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(v.get("deadline_ms").unwrap().as_u64(), Some(1));
        assert!(v.get("elapsed_ms").unwrap().as_u64().unwrap() > 1);
        if server.cache().stats().entries == 1 {
            break;
        }
        assert!(attempt < 50, "deadline fired before the prepare stage on every attempt");
    }
    // ...but the prepare it admitted stays cached: the retry without a
    // deadline is a spec-memo hit.
    assert_eq!(server.cache().stats().entries, 1, "deadline must not discard the prepare");
    let retry = call(
        &server,
        r#"{"id":2,"verb":"recover","graph":{"name":"09-com-Youtube","scale":0.05},"alpha":0.05}"#,
    );
    assert_eq!(retry.get("ok").unwrap().as_bool(), Some(true), "{retry:?}");
    assert_eq!(server.cache().stats().hits, 1);

    server.stop();
    server.wait();
}

#[test]
fn shutdown_verb_stops_the_daemon_and_unlinks_the_socket() {
    let server = start("shutdown", |_| {});
    let path = server.socket().to_path_buf();
    let v = call(&server, r#"{"id":1,"verb":"shutdown"}"#);
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("stopping").unwrap().as_bool(), Some(true));
    server.wait(); // must return promptly — the verb stops the acceptor
    assert!(!path.exists(), "socket unlinked on shutdown");
}

/// Unique-per-test snapshot directory.
fn snapdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pdg-snapdir-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn snapshot_warm_start_across_restart_serves_byte_identical_responses() {
    let dir = snapdir("warm");
    let line = format!(
        r#"{{"id":1,"verb":"recover","graph":{{"name":"15-M6","scale":{SCALE}}},"alpha":0.05,"return_edges":true}}"#
    );

    // Cold daemon: the first request misses both the in-memory cache and
    // the (empty) snapshot dir, prepares in full, and writes back.
    let server = start("warm1", |cfg| cfg.snapshot_dir = Some(dir.clone()));
    let mut client = Client::connect(server.socket()).unwrap();
    let cold = client.call_line(&line).unwrap();
    assert!(cold.contains(r#""ok":true"#), "{cold}");
    let snap = server.snapshot_stats();
    assert_eq!(snap.misses, 1, "no snapshot on disk yet");
    assert_eq!(snap.saves, 1, "prepare written back");
    assert_eq!(snap.hits, 0);
    assert_eq!(snap.load_failures, 0);
    drop(client);
    server.stop();
    server.wait();

    // Exactly one fingerprint-keyed snapshot landed on disk.
    let files: Vec<_> =
        std::fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
    assert_eq!(files.len(), 1, "{files:?}");
    assert_eq!(files[0].extension().unwrap(), "pdsnap");

    // Restarted daemon, same dir: the first request is answered from the
    // warm load — and is byte-identical to the cold daemon's response.
    let server = start("warm2", |cfg| cfg.snapshot_dir = Some(dir.clone()));
    let mut client = Client::connect(server.socket()).unwrap();
    let warm = client.call_line(&line).unwrap();
    assert_eq!(warm, cold, "warm-start response must be byte-identical");
    let snap = server.snapshot_stats();
    assert_eq!(snap.hits, 1, "first request after restart is a warm load");
    assert_eq!(snap.misses, 0);
    assert_eq!(snap.load_failures, 0);
    assert_eq!(snap.saves, 0, "a warm load is not re-saved");
    // Second identical request: plain in-memory hit, snapshot untouched.
    let again = client.call_line(&line).unwrap();
    assert_eq!(again, cold);
    assert_eq!(server.snapshot_stats().hits, 1);

    // The stats verb reports the same counters over the wire.
    let v = call(&server, r#"{"id":9,"verb":"stats"}"#);
    let s = v.get("snapshot").unwrap();
    assert_eq!(s.get("hits").unwrap().as_u64(), Some(1));
    assert_eq!(s.get("misses").unwrap().as_u64(), Some(0));
    assert_eq!(s.get("load_failures").unwrap().as_u64(), Some(0));
    assert_eq!(s.get("saves").unwrap().as_u64(), Some(0));

    server.stop();
    server.wait();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_snapshot_falls_back_to_full_prepare_and_heals() {
    let dir = snapdir("corrupt");
    let line = format!(
        r#"{{"id":1,"verb":"recover","graph":{{"name":"15-M6","scale":{SCALE}}},"alpha":0.05,"return_edges":true}}"#
    );

    // Warm the snapshot dir, then corrupt the file on disk.
    let server = start("corr1", |cfg| cfg.snapshot_dir = Some(dir.clone()));
    let cold = {
        let mut client = Client::connect(server.socket()).unwrap();
        client.call_line(&line).unwrap()
    };
    server.stop();
    server.wait();
    let path = std::fs::read_dir(&dir).unwrap().next().unwrap().unwrap().path();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    // Restarted daemon: the rejected snapshot is *counted* and the
    // request falls back to a full prepare — same bytes served, nothing
    // poisoned — and the write-back heals the corrupt file.
    let server = start("corr2", |cfg| cfg.snapshot_dir = Some(dir.clone()));
    let mut client = Client::connect(server.socket()).unwrap();
    let resp = client.call_line(&line).unwrap();
    assert_eq!(resp, cold, "fallback prepare serves the same bytes");
    let snap = server.snapshot_stats();
    assert_eq!(snap.load_failures, 1, "corrupt snapshot counted as a load failure");
    assert_eq!(snap.hits, 0);
    assert_eq!(snap.misses, 0);
    assert_eq!(snap.saves, 1, "the fresh prepare healed the snapshot");
    drop(client);
    server.stop();
    server.wait();

    // Third start: the healed snapshot warm-loads cleanly.
    let server = start("corr3", |cfg| cfg.snapshot_dir = Some(dir.clone()));
    let mut client = Client::connect(server.socket()).unwrap();
    assert_eq!(client.call_line(&line).unwrap(), cold);
    assert_eq!(server.snapshot_stats().hits, 1);

    server.stop();
    server.wait();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bombard_warm_compare_runs_cold_and_warm_passes() {
    let dir = snapdir("compare");
    let server = start("compare", |cfg| {
        cfg.max_in_flight = 8;
        cfg.snapshot_dir = Some(dir.clone());
    });
    let cfg = BombardConfig {
        socket: server.socket().to_path_buf(),
        requests: 12,
        clients: 2,
        graphs: vec!["15-M6".to_string()],
        alphas: vec![0.02, 0.05],
        scale: SCALE,
        seed: 42,
        deadline_ms: 0,
        shutdown: false,
    };
    let report = bombard::run_compare(&cfg).unwrap();
    assert_eq!(report.cold.failed, 0, "{report:?}");
    assert_eq!(report.warm.failed, 0, "{report:?}");
    assert_eq!(report.cold.sent, 12);
    assert_eq!(report.warm.sent, 12);
    // The cold pass wrote the snapshot; the warm pass (after evict-all)
    // re-resolved the spec from it.
    let snap = server.snapshot_stats();
    assert!(snap.saves >= 1, "{snap:?}");
    assert!(snap.hits >= 1, "{snap:?}");
    assert!(report.render().contains("cold/warm elapsed ratio"));

    server.stop();
    server.wait();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bombard_mixed_load_completes_with_zero_failures() {
    let server = start("bombard", |cfg| cfg.max_in_flight = 8);
    let cfg = BombardConfig {
        socket: server.socket().to_path_buf(),
        requests: 32,
        clients: 3,
        graphs: vec!["15-M6".to_string(), "07-com-DBLP".to_string()],
        alphas: vec![0.02, 0.05],
        scale: SCALE,
        seed: 42,
        deadline_ms: 0,
        shutdown: false,
    };
    let report = bombard::run(&cfg).unwrap();
    assert_eq!(report.sent, 32);
    assert_eq!(report.failed, 0, "{report:?}");
    assert_eq!(report.ok + report.overloaded + report.deadline_exceeded, 32);
    assert!(report.ok > 0);
    assert!(report.p50_us > 0.0 && report.p99_us >= report.p95_us && report.p95_us >= report.p50_us);
    assert!(report.throughput_rps > 0.0);
    let rendered = report.render();
    assert!(rendered.contains("p50") && rendered.contains("p95") && rendered.contains("p99"));

    // Replays are deterministic: the same config generates the same mix.
    assert_eq!(bombard::request_lines(&cfg), bombard::request_lines(&cfg));

    server.stop();
    server.wait();
}
