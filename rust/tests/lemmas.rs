//! Property tests for the paper's lemmas (Section III / Appendix A).
//!
//! These are the correctness backbone of the subtask decomposition: if
//! Lemma 6/7 failed on any input, pdGRASS's outer parallelism would be
//! unsound (edges skipped across subtasks that are actually similar).

use pdgrass::gen;
use pdgrass::graph::Graph;
use pdgrass::recovery::strict::{beta_star, neighborhoods};
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::{build_spanning, off_tree_edges, OffTreeEdge, Spanning};
use pdgrass::util::proptest::{check, Config};
use pdgrass::util::Rng;

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.below(3) {
        0 => gen::grid(4 + rng.below(12), 4 + rng.below(12), 0.6, rng),
        1 => gen::hub_graph(60 + rng.below(300), 1 + rng.below(3), 40 + rng.below(100), rng),
        _ => gen::community(
            gen::CommunityParams {
                n: 100 + rng.below(400),
                mean_size: 8.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 60,
            },
            rng,
        ),
    }
}

/// Reference implementation of Definition 5: is `e2` strictly similar to a
/// *recovered* `e1`? (Direct set membership, no tag machinery.)
fn strictly_similar(sp: &Spanning, e1: &OffTreeEdge, e2: &OffTreeEdge, cap: u32) -> bool {
    let (su, sv, _) = neighborhoods(sp, e1, cap);
    let in_su = |x: u32| su.contains(&x);
    let in_sv = |x: u32| sv.contains(&x);
    (in_su(e2.u) && in_sv(e2.v)) || (in_sv(e2.u) && in_su(e2.v))
}

/// Lemma 6 + 7: strictly similar edges share their LCA; different LCAs →
/// never strictly similar.
#[test]
fn lemma6_7_strictly_similar_edges_share_lca() {
    check(Config { cases: 40, base_seed: 0x61 }, "lemma6", |rng| {
        let g = random_graph(rng);
        let sp = build_spanning(&g);
        let off = off_tree_edges(&g, &sp);
        if off.len() < 2 {
            return Ok(());
        }
        // sample pairs; for any strictly-similar pair the LCAs must match
        for _ in 0..200 {
            let a = &off[rng.below(off.len())];
            let b = &off[rng.below(off.len())];
            if a.eid == b.eid {
                continue;
            }
            if strictly_similar(&sp, a, b, 8) && a.lca != b.lca {
                return Err(format!(
                    "edges ({},{}) lca={} and ({},{}) lca={} strictly similar with different LCAs",
                    a.u, a.v, a.lca, b.u, b.v, b.lca
                ));
            }
        }
        Ok(())
    });
}

/// Lemma 8: strict similarity is non-commutative — there exist pairs where
/// A-similar-to-B but not B-similar-to-A. (Existence over the case sweep:
/// asymmetry must show up somewhere, and symmetric pairs must agree on
/// the similarity verdict's LCA precondition.)
#[test]
fn lemma8_non_commutative_exists() {
    let mut found_asymmetry = false;
    check(Config { cases: 60, base_seed: 0x62 }, "lemma8", |rng| {
        let g = random_graph(rng);
        let sp = build_spanning(&g);
        let off = off_tree_edges(&g, &sp);
        for _ in 0..200 {
            if off.len() < 2 {
                break;
            }
            let a = &off[rng.below(off.len())];
            let b = &off[rng.below(off.len())];
            if a.eid == b.eid {
                continue;
            }
            let ab = strictly_similar(&sp, a, b, 8);
            let ba = strictly_similar(&sp, b, a, 8);
            if ab != ba {
                found_asymmetry = true;
            }
        }
        Ok(())
    });
    assert!(found_asymmetry, "no asymmetric pair found — Lemma 8 stress insufficient");
}

/// β* (Eq. 8) is capped by both endpoint-to-LCA distances and the constant.
#[test]
fn beta_star_bounds() {
    check(Config { cases: 30, base_seed: 0x63 }, "beta_star", |rng| {
        let g = random_graph(rng);
        let sp = build_spanning(&g);
        for e in off_tree_edges(&g, &sp) {
            for cap in [0u32, 1, 8, 100] {
                let b = beta_star(&sp, &e, cap);
                let dl = sp.tree.depth[e.lca as usize];
                let du = sp.tree.depth[e.u as usize] - dl;
                let dv = sp.tree.depth[e.v as usize] - dl;
                if b > cap || b > du || b > dv {
                    return Err(format!("β*={b} exceeds bounds (cap={cap}, du={du}, dv={dv})"));
                }
            }
        }
        Ok(())
    });
}

/// The recovery respects the strict condition: no recovered edge is
/// strictly similar to an earlier-recovered edge of the same subtask.
#[test]
fn recovered_set_is_strictly_independent() {
    check(Config { cases: 20, base_seed: 0x64 }, "independence", |rng| {
        let g = random_graph(rng);
        let sp = build_spanning(&g);
        let params = Params {
            alpha: 0.5, // big target → plenty of recovered edges
            ..Params::new(0.5, 2)
        };
        let r = recovery::pdgrass(&g, &sp, &params);
        if r.passes > 1 {
            // fallback passes intentionally re-admit similar edges
            return Ok(());
        }
        let off = off_tree_edges(&g, &sp);
        let by_eid: std::collections::HashMap<u32, &OffTreeEdge> =
            off.iter().map(|e| (e.eid, e)).collect();
        let rec: Vec<&OffTreeEdge> = r.edges.iter().map(|eid| by_eid[eid]).collect();
        for i in 0..rec.len() {
            for j in (i + 1)..rec.len().min(i + 40) {
                // rec is in score order (recovery order within subtask)
                if rec[i].lca == rec[j].lca && strictly_similar(&sp, rec[i], rec[j], 8) {
                    return Err(format!(
                        "recovered edge {:?} strictly similar to earlier {:?}",
                        rec[j], rec[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Serial pdGRASS equals every parallel strategy on random inputs
/// (the determinism guarantee that makes the parallelization safe).
#[test]
fn strategies_equivalent_on_random_graphs() {
    check(Config { cases: 15, base_seed: 0x65 }, "strategies", |rng| {
        let g = random_graph(rng);
        let sp = build_spanning(&g);
        let mk = |strategy| Params {
            strategy,
            cutoff_edges: 50, // force the inner/sharded paths to actually run
            shard_min: 16,    // small shards so Sharded splits at test scale
            ..Params::new(0.1, 4)
        };
        let base = recovery::pdgrass(&g, &sp, &mk(Strategy::Serial));
        for s in [Strategy::Outer, Strategy::Inner, Strategy::Mixed, Strategy::Sharded] {
            let r = recovery::pdgrass(&g, &sp, &mk(s));
            if r.edges != base.edges {
                return Err(format!("{s:?} diverged from serial"));
            }
        }
        Ok(())
    });
}
