//! Session-API integration: prepare-once/recover-many equivalence with
//! fresh end-to-end runs, concurrent recovery from a shared `Prepared`,
//! and typed errors at the library boundary.

use pdgrass::graph::Graph;
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::{Error, Pipeline, Prepared, RecoverOpts, Sparsify};

/// Recovering at α = 0.02 and then α = 0.10 from ONE `Prepared` yields
/// bitwise-identical edge sets to two fresh end-to-end runs that rebuild
/// steps 1–3 from scratch with the pre-session wiring.
#[test]
fn shared_prepared_matches_fresh_end_to_end_runs() {
    let (name, scale, seed) = ("07-com-DBLP", 0.05, 11);
    let prepared = Sparsify::suite(name, scale, seed).unwrap().prepare().unwrap();
    for alpha in [0.02, 0.10] {
        let shared = prepared.recover(&RecoverOpts::with_threads(alpha, 2)).unwrap();
        // fresh run: new graph, new spanning tree, steps 1–4 end to end
        let g = pdgrass::gen::suite::build(name, scale, seed);
        let sp = build_spanning(&g);
        let fresh = recovery::pdgrass(&g, &sp, &Params::new(alpha, 2));
        assert_eq!(shared.edges(), fresh.edges.as_slice(), "alpha={alpha}");
        assert_eq!(shared.passes(), fresh.passes, "alpha={alpha}");
    }
}

/// The same holds for the feGRASS baseline recovered through the session.
#[test]
fn shared_prepared_fegrass_matches_fresh_run() {
    let (name, scale, seed) = ("01-mi2010", 0.05, 3);
    let prepared = Sparsify::suite(name, scale, seed).unwrap().prepare().unwrap();
    let shared = prepared.fegrass(&RecoverOpts::with_threads(0.05, 1)).unwrap();
    let g = pdgrass::gen::suite::build(name, scale, seed);
    let sp = build_spanning(&g);
    let fresh = recovery::fegrass(&g, &sp, &Params::new(0.05, 1));
    assert_eq!(shared.edges(), fresh.edges.as_slice());
    assert_eq!(shared.passes(), fresh.passes);
}

/// `Prepared` is `Sync`: two threads recover from the same session
/// concurrently and reproduce the single-thread result exactly.
#[test]
fn prepared_recovers_concurrently_from_two_threads() {
    let prepared = Sparsify::suite("15-M6", 0.03, 5).unwrap().prepare().unwrap();
    let opts = RecoverOpts {
        strategy: Strategy::Serial,
        threads: 1,
        block: 1,
        ..RecoverOpts::new(0.05)
    };
    let baseline = prepared.recover(&opts).unwrap().edges().to_vec();
    let p = &prepared;
    std::thread::scope(|s| {
        let h1 = s.spawn(move || p.recover(&opts).unwrap().edges().to_vec());
        let h2 = s.spawn(move || p.recover(&opts).unwrap().edges().to_vec());
        assert_eq!(h1.join().unwrap(), baseline);
        assert_eq!(h2.join().unwrap(), baseline);
    });
}

/// Any (strategy, threads) combination recovered from one `Prepared`
/// agrees with the serial result — scheduling independence survives the
/// prepare/recover split.
#[test]
fn strategies_agree_on_shared_prepared() {
    let prepared = Sparsify::suite("11-citationCiteseer", 0.03, 9).unwrap().prepare().unwrap();
    let serial = prepared
        .recover(&RecoverOpts {
            strategy: Strategy::Serial,
            ..RecoverOpts::with_threads(0.05, 1)
        })
        .unwrap()
        .edges()
        .to_vec();
    for strategy in [Strategy::Outer, Strategy::Inner, Strategy::Mixed, Strategy::Sharded] {
        let opts = RecoverOpts {
            strategy,
            // small cutoff so Mixed/Inner/Sharded exercise the large-subtask path
            cutoff_edges: 200,
            // small shards so Sharded actually splits on a test-scale graph
            shard_min: 64,
            ..RecoverOpts::with_threads(0.05, 4)
        };
        let r = prepared.recover(&opts).unwrap();
        assert_eq!(r.edges(), serial.as_slice(), "strategy {strategy:?} diverged");
    }
}

/// The full session flow: recover → sparsifier → pcg → write_mtx, with
/// the sparsifier size law holding per α.
#[test]
fn session_flow_end_to_end() {
    let prepared = Sparsify::suite("14-NACA0015", 0.05, 7).unwrap().prepare().unwrap();
    let n = prepared.graph().num_vertices();
    for alpha in [0.02, 0.10] {
        let r = prepared.recover(&RecoverOpts::new(alpha)).unwrap();
        let p = r.sparsifier();
        let expect = n - 1 + (alpha * n as f64).ceil() as usize;
        assert_eq!(p.num_edges(), expect, "alpha={alpha}");
        let outcome = p.pcg(42, 1e-3, 50_000).unwrap().require_converged().unwrap();
        assert!(outcome.iterations > 0);
        assert_eq!(outcome.history.len(), outcome.iterations);
    }
    // export + re-read round trip
    let r = prepared.recover(&RecoverOpts::new(0.05)).unwrap();
    let p = r.sparsifier();
    let dir = std::env::temp_dir().join("pdgrass_session");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sparsifier.mtx");
    p.write_mtx(&path).unwrap();
    let back = pdgrass::graph::read_mtx(&path).unwrap();
    assert_eq!(back.num_edges(), p.num_edges());
    std::fs::remove_file(&path).ok();
}

/// Io failures surface as the typed `Error::Io`.
#[test]
fn write_mtx_failure_is_typed_io_error() {
    let prepared = Sparsify::suite("01-mi2010", 0.02, 1).unwrap().prepare().unwrap();
    let r = prepared.recover(&RecoverOpts::new(0.05)).unwrap();
    let p = r.sparsifier();
    let bogus = std::path::Path::new("/no/such/dir/ever/sparsifier.mtx");
    match p.write_mtx(bogus) {
        Err(Error::Io(_)) => {}
        other => panic!("expected Io error, got {other:?}"),
    }
}

/// Assert two `Prepared` states are bitwise identical: spanning tree,
/// score-sorted off-tree list (f64 fields compared by bits), and the
/// subtask decomposition.
fn assert_prepared_bitwise_equal(a: &Prepared, b: &Prepared, label: &str) {
    assert_eq!(a.spanning().root, b.spanning().root, "{label}: root");
    assert_eq!(a.spanning().is_tree_edge, b.spanning().is_tree_edge, "{label}: tree edges");
    assert_eq!(a.num_off_tree(), b.num_off_tree(), "{label}: off-tree count");
    for (x, y) in a.off_tree().iter().zip(b.off_tree()) {
        assert_eq!(x.eid, y.eid, "{label}: off order");
        assert_eq!(x.lca, y.lca, "{label}: lca");
        assert_eq!(x.score.to_bits(), y.score.to_bits(), "{label}: score bits");
        assert_eq!(x.resistance.to_bits(), y.resistance.to_bits(), "{label}: resistance bits");
    }
    assert_eq!(a.subtasks().len(), b.subtasks().len(), "{label}: subtask count");
    for (x, y) in a.subtasks().iter().zip(b.subtasks()) {
        assert_eq!(x.lca, y.lca, "{label}: subtask lca");
        assert_eq!(x.idxs, y.idxs, "{label}: subtask members");
    }
}

/// The adversarial graph shapes from the recovery property suite: a
/// hub-star (one giant LCA subtask) and a pure tree (zero off-tree
/// edges), plus a random community graph.
fn equivalence_graphs() -> Vec<(&'static str, Graph)> {
    let community = pdgrass::gen::community(
        pdgrass::gen::CommunityParams {
            n: 1200,
            mean_size: 10.0,
            tail: 1.7,
            intra_p: 0.5,
            bridges: 2,
            max_size: 80,
        },
        &mut pdgrass::util::Rng::new(23),
    );
    let hub = pdgrass::gen::hub_graph(3000, 1, 2500, &mut pdgrass::util::Rng::new(7));
    let n = 400usize;
    let tree_edges: Vec<(u32, u32, f64)> =
        (0..n - 1).map(|i| (i as u32, i as u32 + 1, 1.0 + (i % 3) as f64)).collect();
    let tree = Graph::from_edges(n, &tree_edges);
    vec![("community", community), ("hub-star", hub), ("pure-tree", tree)]
}

/// Satellite property: `prepare_streamed()` yields bitwise-identical
/// `Prepared` state, recovered-edge sets, `Stats`, and PCG iterates to
/// the barrier path across threads {1, 2, 8}, on random + adversarial
/// (hub-star, pure-tree) graphs.
#[test]
fn streamed_prepare_and_recover_match_barrier_bitwise() {
    for (label, g) in equivalence_graphs() {
        let barrier = Sparsify::graph(g.clone()).prepare().unwrap();
        for threads in [1usize, 2, 8] {
            let streamed = Sparsify::graph(g.clone()).threads(threads).prepare_streamed().unwrap();
            assert_eq!(streamed.pipeline(), Pipeline::Streamed);
            assert_prepared_bitwise_equal(&streamed, &barrier, &format!("{label} t={threads}"));

            // Pure trees have no off-tree edges: α validation aside, the
            // interesting recovery comparisons need recoverable edges.
            if streamed.num_off_tree() == 0 {
                continue;
            }
            // Streamed recovery from the streamed session vs barrier
            // recovery from the barrier session: same edges, stats, trace.
            // Block/shard/cutoff pinned (stats depend on them); only the
            // thread count and the pipeline discipline vary.
            let b_opts = RecoverOpts {
                strategy: Strategy::Sharded,
                cutoff_edges: 200,
                shard_min: 64,
                block: 4,
                ..RecoverOpts::with_threads(0.10, threads)
            };
            let s_opts = RecoverOpts { pipeline: Pipeline::Streamed, ..b_opts };
            let br = barrier.recover_traced(&b_opts).unwrap();
            let sr = streamed.recover_traced(&s_opts).unwrap();
            assert_eq!(sr.edges(), br.edges(), "{label} t={threads}: recovered set");
            assert_eq!(sr.passes(), br.passes(), "{label} t={threads}: passes");
            assert_eq!(
                format!("{:?}", sr.stats()),
                format!("{:?}", br.stats()),
                "{label} t={threads}: stats"
            );
            assert_eq!(
                sr.trace().unwrap().subtask_costs,
                br.trace().unwrap().subtask_costs,
                "{label} t={threads}: trace"
            );

            // PCG iterates are bitwise identical too: same sparsifier,
            // same fixed-tree reductions.
            let bo = br.sparsifier().pcg(42, 1e-3, 50_000).unwrap();
            let so = sr.sparsifier().pcg(42, 1e-3, 50_000).unwrap();
            assert_eq!(so.iterations, bo.iterations, "{label} t={threads}: pcg iterations");
            assert_eq!(so.converged, bo.converged, "{label} t={threads}");
            assert_eq!(so.history.len(), bo.history.len(), "{label} t={threads}");
            for (x, y) in so.history.iter().zip(&bo.history) {
                assert_eq!(x.to_bits(), y.to_bits(), "{label} t={threads}: pcg history bits");
            }
        }
    }
}

/// Satellite regression: `Sparsifier::pcg` dispatches to the pooled
/// solver with the session's thread count (it used to hardcode the
/// serial path and silently ignore `Sparsify::threads`). The evaluation
/// must stay bitwise identical to the serial baseline at every thread
/// count, on both pipeline disciplines — level-scheduled triangular
/// solves and fixed-tree reductions included.
#[test]
fn session_pcg_is_bitwise_identical_across_threads_and_pipelines() {
    let g = pdgrass::gen::grid(40, 40, 0.4, &mut pdgrass::util::Rng::new(19));
    let opts = RecoverOpts::new(0.10);
    let base_sess = Sparsify::graph(g.clone()).threads(1).prepare().unwrap();
    assert_eq!(base_sess.threads(), 1);
    let base = base_sess.recover(&opts).unwrap().sparsifier().pcg(42, 1e-3, 50_000).unwrap();
    assert!(base.converged);
    for pipeline in [Pipeline::Barrier, Pipeline::Streamed] {
        for threads in [1usize, 2, 8] {
            let sess = Sparsify::graph(g.clone()).threads(threads).pipeline(pipeline);
            let prepared = if pipeline == Pipeline::Streamed {
                sess.prepare_streamed().unwrap()
            } else {
                sess.prepare().unwrap()
            };
            assert_eq!(prepared.threads(), threads);
            let got =
                prepared.recover(&opts).unwrap().sparsifier().pcg(42, 1e-3, 50_000).unwrap();
            let label = format!("{pipeline:?} t={threads}");
            assert_eq!(got.iterations, base.iterations, "{label}: iterations");
            assert_eq!(got.converged, base.converged, "{label}: converged");
            assert_eq!(got.history.len(), base.history.len(), "{label}: history len");
            for (x, y) in got.history.iter().zip(&base.history) {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: history bits");
            }
        }
    }
}

/// Prepare-side instrumentation: a recover-many sweep pays prepare once.
#[test]
fn prepare_and_recover_counters_track_the_split() {
    let prepares_before = pdgrass::session::prepare_count();
    let recovers_before = pdgrass::session::recover_count();
    let prepared = Sparsify::suite("08-com-Amazon", 0.03, 2).unwrap().prepare().unwrap();
    for alpha in [0.02, 0.05, 0.10] {
        prepared.recover(&RecoverOpts::new(alpha)).unwrap();
    }
    // Other tests may run concurrently in this process, so the deltas are
    // lower bounds — but a sweep of 3 recoveries from one session must
    // add at least (1 prepare, 3 recoveries).
    assert!(pdgrass::session::prepare_count() >= prepares_before + 1);
    assert!(pdgrass::session::recover_count() >= recovers_before + 3);
}
