//! Session-API integration: prepare-once/recover-many equivalence with
//! fresh end-to-end runs, concurrent recovery from a shared `Prepared`,
//! and typed errors at the library boundary.

use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::{Error, RecoverOpts, Sparsify};

/// Recovering at α = 0.02 and then α = 0.10 from ONE `Prepared` yields
/// bitwise-identical edge sets to two fresh end-to-end runs that rebuild
/// steps 1–3 from scratch with the pre-session wiring.
#[test]
fn shared_prepared_matches_fresh_end_to_end_runs() {
    let (name, scale, seed) = ("07-com-DBLP", 0.05, 11);
    let prepared = Sparsify::suite(name, scale, seed).unwrap().prepare().unwrap();
    for alpha in [0.02, 0.10] {
        let shared = prepared.recover(&RecoverOpts::with_threads(alpha, 2)).unwrap();
        // fresh run: new graph, new spanning tree, steps 1–4 end to end
        let g = pdgrass::gen::suite::build(name, scale, seed);
        let sp = build_spanning(&g);
        let fresh = recovery::pdgrass(&g, &sp, &Params::new(alpha, 2));
        assert_eq!(shared.edges(), fresh.edges.as_slice(), "alpha={alpha}");
        assert_eq!(shared.passes(), fresh.passes, "alpha={alpha}");
    }
}

/// The same holds for the feGRASS baseline recovered through the session.
#[test]
fn shared_prepared_fegrass_matches_fresh_run() {
    let (name, scale, seed) = ("01-mi2010", 0.05, 3);
    let prepared = Sparsify::suite(name, scale, seed).unwrap().prepare().unwrap();
    let shared = prepared.fegrass(&RecoverOpts::with_threads(0.05, 1)).unwrap();
    let g = pdgrass::gen::suite::build(name, scale, seed);
    let sp = build_spanning(&g);
    let fresh = recovery::fegrass(&g, &sp, &Params::new(0.05, 1));
    assert_eq!(shared.edges(), fresh.edges.as_slice());
    assert_eq!(shared.passes(), fresh.passes);
}

/// `Prepared` is `Sync`: two threads recover from the same session
/// concurrently and reproduce the single-thread result exactly.
#[test]
fn prepared_recovers_concurrently_from_two_threads() {
    let prepared = Sparsify::suite("15-M6", 0.03, 5).unwrap().prepare().unwrap();
    let opts = RecoverOpts {
        strategy: Strategy::Serial,
        threads: 1,
        block: 1,
        ..RecoverOpts::new(0.05)
    };
    let baseline = prepared.recover(&opts).unwrap().edges().to_vec();
    let p = &prepared;
    std::thread::scope(|s| {
        let h1 = s.spawn(move || p.recover(&opts).unwrap().edges().to_vec());
        let h2 = s.spawn(move || p.recover(&opts).unwrap().edges().to_vec());
        assert_eq!(h1.join().unwrap(), baseline);
        assert_eq!(h2.join().unwrap(), baseline);
    });
}

/// Any (strategy, threads) combination recovered from one `Prepared`
/// agrees with the serial result — scheduling independence survives the
/// prepare/recover split.
#[test]
fn strategies_agree_on_shared_prepared() {
    let prepared = Sparsify::suite("11-citationCiteseer", 0.03, 9).unwrap().prepare().unwrap();
    let serial = prepared
        .recover(&RecoverOpts {
            strategy: Strategy::Serial,
            ..RecoverOpts::with_threads(0.05, 1)
        })
        .unwrap()
        .edges()
        .to_vec();
    for strategy in [Strategy::Outer, Strategy::Inner, Strategy::Mixed, Strategy::Sharded] {
        let opts = RecoverOpts {
            strategy,
            // small cutoff so Mixed/Inner/Sharded exercise the large-subtask path
            cutoff_edges: 200,
            // small shards so Sharded actually splits on a test-scale graph
            shard_min: 64,
            ..RecoverOpts::with_threads(0.05, 4)
        };
        let r = prepared.recover(&opts).unwrap();
        assert_eq!(r.edges(), serial.as_slice(), "strategy {strategy:?} diverged");
    }
}

/// The full session flow: recover → sparsifier → pcg → write_mtx, with
/// the sparsifier size law holding per α.
#[test]
fn session_flow_end_to_end() {
    let prepared = Sparsify::suite("14-NACA0015", 0.05, 7).unwrap().prepare().unwrap();
    let n = prepared.graph().num_vertices();
    for alpha in [0.02, 0.10] {
        let r = prepared.recover(&RecoverOpts::new(alpha)).unwrap();
        let p = r.sparsifier();
        let expect = n - 1 + (alpha * n as f64).ceil() as usize;
        assert_eq!(p.num_edges(), expect, "alpha={alpha}");
        let outcome = p.pcg(42, 1e-3, 50_000).unwrap().require_converged().unwrap();
        assert!(outcome.iterations > 0);
        assert_eq!(outcome.history.len(), outcome.iterations);
    }
    // export + re-read round trip
    let r = prepared.recover(&RecoverOpts::new(0.05)).unwrap();
    let p = r.sparsifier();
    let dir = std::env::temp_dir().join("pdgrass_session");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sparsifier.mtx");
    p.write_mtx(&path).unwrap();
    let back = pdgrass::graph::read_mtx(&path).unwrap();
    assert_eq!(back.num_edges(), p.num_edges());
    std::fs::remove_file(&path).ok();
}

/// Io failures surface as the typed `Error::Io`.
#[test]
fn write_mtx_failure_is_typed_io_error() {
    let prepared = Sparsify::suite("01-mi2010", 0.02, 1).unwrap().prepare().unwrap();
    let r = prepared.recover(&RecoverOpts::new(0.05)).unwrap();
    let p = r.sparsifier();
    let bogus = std::path::Path::new("/no/such/dir/ever/sparsifier.mtx");
    match p.write_mtx(bogus) {
        Err(Error::Io(_)) => {}
        other => panic!("expected Io error, got {other:?}"),
    }
}

/// Prepare-side instrumentation: a recover-many sweep pays prepare once.
#[test]
fn prepare_and_recover_counters_track_the_split() {
    let prepares_before = pdgrass::session::prepare_count();
    let recovers_before = pdgrass::session::recover_count();
    let prepared = Sparsify::suite("08-com-Amazon", 0.03, 2).unwrap().prepare().unwrap();
    for alpha in [0.02, 0.05, 0.10] {
        prepared.recover(&RecoverOpts::new(alpha)).unwrap();
    }
    // Other tests may run concurrently in this process, so the deltas are
    // lower bounds — but a sweep of 3 recoveries from one session must
    // add at least (1 prepare, 3 recoveries).
    assert!(pdgrass::session::prepare_count() >= prepares_before + 1);
    assert!(pdgrass::session::recover_count() >= recovers_before + 3);
}
