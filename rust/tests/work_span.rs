//! Empirical validation of Table I (work–span analysis).
//!
//! The measured work counters must respect the paper's asymptotic bounds:
//! step 4's work is `O(Σ|Sᵢ|²)`, and the simulated span decomposes into
//! the inner-parallel + serial-subtask terms. These tests check the
//! bounds numerically on suite-family inputs (constant factors included).

use pdgrass::coordinator::schedsim::{simulate, SimParams};
use pdgrass::par;
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

fn traced(g: &pdgrass::graph::Graph, alpha: f64) -> recovery::Recovery {
    let sp = build_spanning(g);
    let params = Params { strategy: Strategy::Serial, ..Params::new(alpha, 1) };
    recovery::pdgrass::pdgrass_traced(g, &sp, &params, true)
}

/// Work bound: total check units ≤ c·Σ|Sᵢ|² + total edges (each candidate
/// probes tags accumulated from earlier recoveries in its subtask).
#[test]
fn step4_work_is_subquadratic_per_subtask() {
    for seed in [1u64, 2] {
        let g = pdgrass::gen::community(
            pdgrass::gen::CommunityParams {
                n: 2000,
                mean_size: 10.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 80,
            },
            &mut Rng::new(seed),
        );
        let r = traced(&g, 1.0);
        let trace = r.trace.unwrap();
        let sum_sq: u64 = trace
            .subtask_costs
            .iter()
            .map(|c| (c.len() as u64) * (c.len() as u64))
            .sum();
        let edges: u64 = trace.subtask_costs.iter().map(|c| c.len() as u64).sum();
        // Each tag probe costs O(tags at the two endpoints); tags per
        // vertex ≤ recovered-in-subtask, so check units ≤ ~4·Σ|Sᵢ|².
        assert!(
            r.stats.check_units <= 8 * sum_sq + 2 * edges,
            "check_units {} vs bound {} (Σ|Sᵢ|²={sum_sq})",
            r.stats.check_units,
            8 * sum_sq + 2 * edges
        );
    }
}

/// Span decomposition: simulated time at p threads is bounded below by
/// the serial spine of the largest inner subtask and above by serial time.
#[test]
fn simulated_span_sandwich() {
    let g = pdgrass::gen::hub_graph(3000, 2, 1200, &mut Rng::new(3));
    let r = traced(&g, 1.0);
    let trace = r.trace.unwrap();
    let t1 = simulate(&trace, &SimParams::new(1)).time();
    for p in [2usize, 4, 8, 32] {
        let mut sp = SimParams::new(p);
        sp.cutoff_frac = 0.10;
        let sim = simulate(&trace, &sp);
        assert!(sim.time() <= t1, "p={p}: simulated time exceeds serial");
        // span lower bound: the serial spine can't be parallelized away
        assert!(sim.time() >= sim.inner_serial);
        // speedup can't exceed p (no superlinear artifacts in the model)
        assert!(
            sim.speedup() <= p as f64 + 1e-9,
            "p={p}: superlinear speedup {}",
            sim.speedup()
        );
    }
}

/// Monotonicity: more threads never simulate slower.
#[test]
fn simulated_time_monotone_in_threads() {
    let g = pdgrass::gen::tri_mesh(60, 60, &mut Rng::new(4));
    let r = traced(&g, 0.1);
    let trace = r.trace.unwrap();
    let mut last = u64::MAX;
    for p in [1usize, 2, 4, 8, 16, 32, 64] {
        let t = simulate(&trace, &SimParams::new(p)).time();
        assert!(t <= last, "p={p}: {t} > previous {last}");
        last = t;
    }
}

/// Pool-contention regression (ISSUE 2): the Mixed-strategy shape nests
/// a reduction *inside* a dynamically scheduled outer loop. Every outer
/// task recruits pool workers that are themselves busy with outer tasks,
/// so this deadlocks unless scope claiming lets callers participate
/// (`par::pool`'s execution model) — and the nested reductions must
/// still produce the deterministic fixed-tree value.
#[test]
fn nested_par_reduce_inside_par_for_completes() {
    let expect: u64 = (0..10_000u64).sum();
    let outer = 24usize;
    let sums: Vec<AtomicU64> = (0..outer).map(|_| AtomicU64::new(0)).collect();
    par::par_for(outer, 4, 1, |i| {
        let s = par::par_reduce(
            10_000,
            4,
            64,
            |r: std::ops::Range<usize>| r.map(|x| x as u64).sum::<u64>(),
            |a, b| a + b,
        );
        sums[i].store(s, Ordering::Relaxed);
    });
    for s in &sums {
        assert_eq!(s.load(Ordering::Relaxed), expect);
    }
}

/// A panic inside the *inner* reduction must unwind through both nesting
/// levels to the caller — and leave the pool serviceable.
#[test]
fn nested_par_reduce_panic_propagates_through_par_for() {
    let result = std::panic::catch_unwind(|| {
        par::par_for(8, 4, 1, |i| {
            let _ = par::par_reduce(
                1000,
                4,
                16,
                |r: std::ops::Range<usize>| {
                    if i == 3 && r.contains(&500) {
                        panic!("inner reduce boom");
                    }
                    r.len() as u64
                },
                |a, b| a + b,
            );
        });
    });
    assert!(result.is_err(), "inner panic must reach the outer caller");
    // The pool survives: both a reduction and an outer loop still run.
    let s = par::par_reduce(
        5000,
        4,
        32,
        |r: std::ops::Range<usize>| r.len() as u64,
        |a, b| a + b,
    );
    assert_eq!(s, 5000);
    let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
    par::par_for(64, 4, 1, |i| {
        hits[i].fetch_add(1, Ordering::Relaxed);
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

/// The quadratic worst case is real: a subtask where nothing is similar
/// does Θ(|S|²) tag-probe work (this is the paper's §IV complexity
/// caveat, kept honest).
#[test]
fn quadratic_worst_case_exists() {
    // β* = 0 (cap 0) → no edge ever similar → every candidate probes all
    // previous tags in its subtask.
    let g = pdgrass::gen::grid(24, 24, 0.8, &mut Rng::new(5));
    let sp = build_spanning(&g);
    let mut params = Params::new(1.0, 1);
    params.beta_cap = 0;
    params.strategy = Strategy::Serial;
    let r = recovery::pdgrass(&g, &sp, &params);
    assert_eq!(r.passes, 1);
    // everything recovered (nothing similar at β*=0 ⇒ S_u = {u})
    assert_eq!(r.edges.len(), sp.num_off_tree().min(params.target(g.num_vertices())));
}
