//! Snapshot integration properties: `save → load` recovers a `Prepared`
//! that is *bitwise identical* to the freshly prepared one — same
//! re-encoded bytes, same recovered edge sets, same PCG convergence
//! histories — across graph shapes × pipelines × thread counts; and
//! every corruption of the container (truncation, bit flip, stale
//! header) is the typed `Error::Snapshot`, never a panic and never a
//! silently-wrong state.

use pdgrass::gen::{self, CommunityParams};
use pdgrass::graph::Graph;
use pdgrass::util::Rng;
use pdgrass::{Error, Pipeline, Prepared, RecoverOpts, Sparsify};

/// Planted-community graph: moderately skewed subtask distribution.
fn community_graph() -> Graph {
    gen::community(
        CommunityParams {
            n: 400,
            mean_size: 8.0,
            tail: 1.8,
            intra_p: 0.6,
            bridges: 2,
            max_size: 40,
        },
        &mut Rng::new(7),
    )
}

/// Hub-star graph: one dominant LCA subtask (the skewed worst case).
fn hub_star_graph() -> Graph {
    gen::hub_graph(400, 4, 60, &mut Rng::new(11))
}

/// Pure tree: zero off-tree edges, zero subtasks — the degenerate
/// container with three empty payload sections.
fn pure_tree_graph() -> Graph {
    let mut rng = Rng::new(13);
    let n = 200usize;
    let mut edges = Vec::with_capacity(n - 1);
    for v in 1..n {
        let parent = rng.below(v) as u32;
        edges.push((parent, v as u32, rng.range_f64(1.0, 10.0)));
    }
    Graph::from_edges(n, &edges)
}

fn graphs() -> Vec<(&'static str, Graph)> {
    vec![
        ("community", community_graph()),
        ("hub-star", hub_star_graph()),
        ("pure-tree", pure_tree_graph()),
    ]
}

fn prepare(g: &Graph, name: &str, pipeline: Pipeline, threads: usize) -> Prepared {
    Sparsify::graph(g.clone())
        .named(name)
        .pipeline(pipeline)
        .threads(threads)
        .prepare()
        .unwrap()
}

/// The core property, over graphs × {Barrier, Streamed} × {1, 2, 8}
/// threads: a snapshot round trip reproduces the fresh `Prepared`
/// exactly. "Exactly" is checked three ways — the loaded state
/// re-encodes to the same bytes, recovers the same edge set, and drives
/// PCG through a bitwise-identical residual history.
#[test]
fn save_load_recover_is_bitwise_identical_to_fresh_prepare() {
    for (name, g) in graphs() {
        for pipeline in [Pipeline::Barrier, Pipeline::Streamed] {
            for threads in [1usize, 2, 8] {
                let fresh = prepare(&g, name, pipeline, threads);
                let bytes = fresh.to_snapshot_bytes();
                let loaded = Prepared::from_snapshot_bytes(&bytes)
                    .unwrap_or_else(|e| panic!("{name}/{pipeline:?}/{threads}: {e}"))
                    .with_threads(threads);

                assert_eq!(loaded.fingerprint(), fresh.fingerprint(), "{name}");
                assert_eq!(loaded.name(), fresh.name(), "{name}");
                assert_eq!(loaded.pipeline(), fresh.pipeline(), "{name}");
                assert_eq!(loaded.num_off_tree(), fresh.num_off_tree(), "{name}");
                assert_eq!(
                    loaded.to_snapshot_bytes(),
                    bytes,
                    "{name}/{pipeline:?}/{threads}: re-encode differs"
                );

                let opts = RecoverOpts::with_threads(0.05, threads);
                let a = fresh.recover(&opts).unwrap();
                let b = loaded.recover(&opts).unwrap();
                assert_eq!(
                    a.edges(),
                    b.edges(),
                    "{name}/{pipeline:?}/{threads}: recovered edges differ"
                );
                assert_eq!(a.passes(), b.passes(), "{name}");

                let ha: Vec<u64> = a
                    .sparsifier()
                    .pcg(42, 1e-3, 2000)
                    .unwrap()
                    .history
                    .iter()
                    .map(|r| r.to_bits())
                    .collect();
                let hb: Vec<u64> = b
                    .sparsifier()
                    .pcg(42, 1e-3, 2000)
                    .unwrap()
                    .history
                    .iter()
                    .map(|r| r.to_bits())
                    .collect();
                assert_eq!(ha, hb, "{name}/{pipeline:?}/{threads}: PCG history differs");
            }
        }
    }
}

/// File-level round trip through `Prepared::save` / `Prepared::load`,
/// plus the load-path error taxonomy: a missing file is `Error::Io`
/// (cache *miss*), a corrupt file is `Error::Snapshot` (load failure).
#[test]
fn file_save_load_round_trips_and_errors_are_typed() {
    let dir = std::env::temp_dir().join(format!("pdgrass-snap-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let fresh = prepare(&community_graph(), "community", Pipeline::Barrier, 2);
    let path = pdgrass::snapshot::file_path(&dir, fresh.fingerprint());
    fresh.save(&path).unwrap();
    let loaded = Prepared::load(&path).unwrap();
    assert_eq!(loaded.to_snapshot_bytes(), fresh.to_snapshot_bytes());

    match Prepared::load(&dir.join("absent.pdsnap")) {
        Err(Error::Io(_)) => {}
        other => panic!("missing file: expected Io, got {other:?}"),
    }

    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let corrupt = dir.join("corrupt.pdsnap");
    std::fs::write(&corrupt, &bytes).unwrap();
    match Prepared::load(&corrupt) {
        Err(Error::Snapshot { .. }) => {}
        other => panic!("corrupt file: expected Snapshot, got {other:?}"),
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// Exhaustive deterministic corruption fuzz on a small container:
/// every single-byte flip, at every offset, in every region — header,
/// section table, each payload section, alignment padding — must be
/// rejected with the typed `Error::Snapshot`. No flip may panic, and no
/// flip may decode (the container has no undetectable single-byte
/// corruption).
#[test]
fn every_single_byte_flip_is_rejected_typed() {
    let fresh = prepare(&pure_tree_graph(), "tree", Pipeline::Barrier, 1);
    let bytes = fresh.to_snapshot_bytes();
    assert!(Prepared::from_snapshot_bytes(&bytes).is_ok(), "baseline must decode");
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x01;
        match Prepared::from_snapshot_bytes(&bad) {
            Err(Error::Snapshot { .. }) => {}
            Ok(_) => panic!("flip at byte {i} decoded successfully"),
            Err(other) => panic!("flip at byte {i}: wrong error type {other:?}"),
        }
        // High bit too: exercises sign/magnitude corruption of floats
        // and lengths, not just low-bit noise.
        let mut bad = bytes.clone();
        bad[i] ^= 0x80;
        match Prepared::from_snapshot_bytes(&bad) {
            Err(Error::Snapshot { .. }) => {}
            Ok(_) => panic!("high flip at byte {i} decoded successfully"),
            Err(other) => panic!("high flip at byte {i}: wrong error type {other:?}"),
        }
    }
}

/// Every truncation length — not a sample, all of them — is rejected
/// typed. Covers mid-header, mid-table, mid-section, and the
/// one-byte-short case.
#[test]
fn every_truncation_is_rejected_typed() {
    let fresh = prepare(&pure_tree_graph(), "tree", Pipeline::Streamed, 1);
    let bytes = fresh.to_snapshot_bytes();
    for len in 0..bytes.len() {
        match Prepared::from_snapshot_bytes(&bytes[..len]) {
            Err(Error::Snapshot { .. }) => {}
            Ok(_) => panic!("truncation to {len} bytes decoded successfully"),
            Err(other) => panic!("truncation to {len}: wrong error type {other:?}"),
        }
    }
    // Trailing garbage is equally stale.
    let mut long = bytes.clone();
    long.push(0);
    assert!(matches!(
        Prepared::from_snapshot_bytes(&long),
        Err(Error::Snapshot { .. })
    ));
}

/// Stale headers are named in the rejection: a bumped version mentions
/// both versions, a foreign fingerprint mentions the mismatch.
#[test]
fn stale_headers_are_rejected_with_named_reasons() {
    let fresh = prepare(&hub_star_graph(), "hub", Pipeline::Barrier, 2);
    let bytes = fresh.to_snapshot_bytes();

    let mut wrong_version = bytes.clone();
    wrong_version[8] = 0xEE;
    match Prepared::from_snapshot_bytes(&wrong_version) {
        Err(Error::Snapshot { why }) => {
            assert!(why.contains("version"), "{why}")
        }
        other => panic!("expected Snapshot, got {other:?}"),
    }

    let mut wrong_magic = bytes.clone();
    wrong_magic[0] = b'X';
    match Prepared::from_snapshot_bytes(&wrong_magic) {
        Err(Error::Snapshot { why }) => {
            assert!(why.contains("magic"), "{why}")
        }
        other => panic!("expected Snapshot, got {other:?}"),
    }

    // A foreign fingerprint survives CRC checks (the header is not
    // CRC'd) but fails the decoded-graph cross-check.
    let mut wrong_fp = bytes.clone();
    wrong_fp[20] ^= 0xFF;
    match Prepared::from_snapshot_bytes(&wrong_fp) {
        Err(Error::Snapshot { why }) => {
            assert!(why.contains("fingerprint"), "{why}")
        }
        other => panic!("expected Snapshot, got {other:?}"),
    }
}

/// Loading does not count as a prepare: the warm path must leave the
/// session-level prepare counter untouched, which is exactly what the
/// daemon's warm-start stats rely on.
#[test]
fn loading_a_snapshot_does_not_bump_the_prepare_counter() {
    let fresh = prepare(&pure_tree_graph(), "tree", Pipeline::Barrier, 1);
    let bytes = fresh.to_snapshot_bytes();
    // The counter is process-global and sibling tests prepare
    // concurrently, so require one clean window rather than a single
    // read pair: a load that *did* bump the counter can never produce
    // `after == before`, while unrelated prepares can only spoil an
    // attempt, not fake a pass.
    let mut loaded = None;
    for _ in 0..64 {
        let before = pdgrass::session::prepare_count();
        let p = Prepared::from_snapshot_bytes(&bytes).unwrap();
        if pdgrass::session::prepare_count() == before {
            loaded = Some(p);
            break;
        }
    }
    let loaded = loaded.expect("no clean counter window in 64 attempts");
    // ...and the loaded state is fully usable for step 4.
    loaded.recover(&RecoverOpts::new(0.05)).unwrap();
}
