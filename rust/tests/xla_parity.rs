//! XLA-path ≡ Rust-path parity: the compiled Pallas ELL kernel must
//! reproduce the pure-Rust CSR SpMV and the PCG iteration counts.
//!
//! Requires `make artifacts` **and** the real `xla` PJRT bindings. In the
//! offline build (vendored `xla` stub, no artifact directory) every test
//! here detects the missing runtime and skips itself instead of failing —
//! the pure-Rust reference path is covered by the rest of the suite.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::{self, Params};
use pdgrass::runtime::{jacobi_pcg_xla, pcg_xla, prepare_spmv, Runtime};
use pdgrass::solver::{pcg, Jacobi, SparsifierPrecond};
use pdgrass::tree::build_spanning;
use pdgrass::util::Rng;

/// Open the artifact runtime, or `None` (with a note) when the XLA path
/// is unavailable in this environment.
fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping XLA parity test (runtime unavailable): {e}");
            None
        }
    }
}

#[test]
fn spmv_parity_across_families() {
    let Some(rt) = runtime() else { return };
    for (name, scale) in [("01-mi2010", 0.05), ("09-com-Youtube", 0.1), ("15-M6", 0.02)] {
        let g = pdgrass::gen::suite::build(name, scale, 3);
        let a = grounded_laplacian(&g, 0);
        let xs = prepare_spmv(&rt, &a).unwrap();
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
        let mut y_xla = vec![0.0; a.n];
        xs.apply(&x, &mut y_xla).unwrap();
        let mut y_ref = vec![0.0; a.n];
        pdgrass::solver::spmv(&a, &x, &mut y_ref);
        let scale_ref: f64 =
            y_ref.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
        for (i, (u, v)) in y_xla.iter().zip(&y_ref).enumerate() {
            assert!(
                (u - v).abs() < 1e-4 * scale_ref,
                "{name} row {i}: {u} vs {v}"
            );
        }
    }
}

#[test]
fn hub_rows_spill_to_tail_and_stay_exact() {
    let Some(rt) = runtime() else { return };
    let g = pdgrass::gen::hub_graph(800, 2, 400, &mut Rng::new(7));
    let a = grounded_laplacian(&g, 0);
    let xs = prepare_spmv(&rt, &a).unwrap();
    assert!(!xs.ell.tail.is_empty(), "hub graph must exercise the COO tail");
    let mut rng = Rng::new(8);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let mut y_xla = vec![0.0; a.n];
    xs.apply(&x, &mut y_xla).unwrap();
    let mut y_ref = vec![0.0; a.n];
    pdgrass::solver::spmv(&a, &x, &mut y_ref);
    let m = y_ref.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
    for (u, v) in y_xla.iter().zip(&y_ref) {
        assert!((u - v).abs() < 5e-4 * m, "{u} vs {v}");
    }
}

#[test]
fn pcg_iteration_parity_with_sparsifier_preconditioner() {
    let Some(rt) = runtime() else { return };
    let g = pdgrass::gen::suite::build("14-NACA0015", 0.04, 9);
    let sp = build_spanning(&g);
    let r = recovery::pdgrass(&g, &sp, &Params::new(0.05, 1));
    let p = recovery::sparsifier(&g, &sp, &r.edges);
    let lg = grounded_laplacian(&g, 0);
    let m = SparsifierPrecond::new(&p).unwrap();
    let mut rng = Rng::new(10);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let rust = pcg(&lg, &b, &m, 1e-3, 50_000);
    let xla = pcg_xla(&rt, &lg, &b, &m, 1e-3, 50_000).unwrap();
    assert!(rust.converged && xla.converged);
    let diff = (rust.iterations as i64 - xla.iterations as i64).abs();
    assert!(
        diff <= (rust.iterations as i64) / 10 + 2,
        "iteration divergence: rust {} vs xla {}",
        rust.iterations,
        xla.iterations
    );
}

#[test]
fn scan_fused_jacobi_matches_rust_jacobi() {
    let Some(rt) = runtime() else { return };
    let g = pdgrass::gen::grid(28, 28, 0.4, &mut Rng::new(11));
    let lg = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(12);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let (x, hist) = jacobi_pcg_xla(&rt, &lg, &b).unwrap();
    let xla_iters = pdgrass::runtime::iterations_to_tol(&hist, 1e-3).expect("must converge");
    let rust = pcg(&lg, &b, &Jacobi::new(&lg).unwrap(), 1e-3, 200);
    assert!(rust.converged);
    let diff = (rust.iterations as i64 - xla_iters as i64).abs();
    assert!(diff <= rust.iterations as i64 / 10 + 3, "{} vs {xla_iters}", rust.iterations);
    // solution actually solves the system
    let mut ax = vec![0.0; lg.n];
    pdgrass::solver::spmv(&lg, &x, &mut ax);
    let relres = ax
        .iter()
        .zip(&b)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
        / b.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(relres < 5e-3, "true residual {relres}");
}

#[test]
fn runtime_caches_compiled_executables() {
    let Some(rt) = runtime() else { return };
    let row = rt.manifest().iter().find(|r| r.kind == "spmv").unwrap().clone();
    let t0 = std::time::Instant::now();
    let _e1 = rt.load(&row).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _e2 = rt.load(&row).unwrap();
    let second = t1.elapsed();
    assert!(second < first / 2, "cache hit {second:?} should beat compile {first:?}");
}
