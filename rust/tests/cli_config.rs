//! CLI + config integration: the launcher surface a user actually touches.

use pdgrass::cli;
use pdgrass::config::{Doc, RunConfig};

fn args(a: &[&str]) -> Vec<String> {
    a.iter().map(|s| s.to_string()).collect()
}

#[test]
fn sparsify_and_evaluate_verbs() {
    cli::run(&args(&["sparsify", "--graph", "01-mi2010", "--alpha", "0.05", "--scale", "0.02"]))
        .unwrap();
    cli::run(&args(&["evaluate", "--graph", "01-mi2010", "--alpha", "0.05", "--scale", "0.02"]))
        .unwrap();
}

#[test]
fn sparsify_writes_mtx() {
    let dir = std::env::temp_dir().join("pdgrass_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("out.mtx");
    cli::run(&args(&[
        "sparsify",
        "--graph",
        "15-M6",
        "--alpha",
        "0.02",
        "--scale",
        "0.02",
        "--out",
        out.to_str().unwrap(),
    ]))
    .unwrap();
    let g = pdgrass::graph::read_mtx(&out).unwrap();
    assert!(g.num_edges() > g.num_vertices() - 1);
    std::fs::remove_file(&out).ok();
}

#[test]
fn config_file_drives_experiments() {
    let dir = std::env::temp_dir().join("pdgrass_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.toml");
    std::fs::write(
        &cfg_path,
        "[run]\nalphas = [0.02]\ngraphs = [\"01-mi2010\"]\nscale = 0.02\ntrials = 1\n",
    )
    .unwrap();
    cli::run(&args(&["table2", "--config", cfg_path.to_str().unwrap()])).unwrap();
    std::fs::remove_file(&cfg_path).ok();
}

#[test]
fn bad_config_is_a_clean_error() {
    let dir = std::env::temp_dir().join("pdgrass_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("bad.toml");
    std::fs::write(&cfg_path, "[run]\nnot_a_key = 3\n").unwrap();
    let err = cli::run(&args(&["table2", "--config", cfg_path.to_str().unwrap()]))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown config key"), "{err}");
    std::fs::remove_file(&cfg_path).ok();
}

#[test]
fn defaults_round_trip() {
    let doc = Doc::parse("").unwrap();
    let cfg = RunConfig::from_doc(&doc).unwrap();
    assert_eq!(cfg.alphas, vec![0.02, 0.05, 0.10]);
    assert!(cfg.graphs.is_empty());
    let p = cfg.pipeline();
    assert_eq!(p.alpha, 0.02);
}
