// Fixture: determinism hazards inside a scoped module (`recovery/`).
// Expected: det-collections (HashMap), det-timing (Instant::now),
// 2 x det-float-fold (untyped .sum(), float .fold).

use std::collections::HashMap;

pub fn total(xs: &[f64]) -> f64 {
    let t = std::time::Instant::now();
    let mut m: HashMap<u32, f64> = HashMap::new();
    for (i, x) in xs.iter().enumerate() {
        m.insert(i as u32, *x);
    }
    let bad: f64 = m.values().sum();
    let worse = xs.iter().fold(0.0, |a, b| a + b);
    bad + worse + t.elapsed().as_secs_f64()
}
