// Fixture: the deterministic counterparts — integer turbofish sums,
// an acknowledged fold, Fx collections, and test-only std collections.
// Expected: no violations.

pub fn count(xs: &[f64]) -> usize {
    xs.iter().map(|_| 1usize).sum::<usize>()
}

pub fn total(xs: &[f64]) -> f64 {
    // audit-ok: fixed-order fold over a slice is deterministic.
    xs.iter().fold(0.0, |a, b| a + b)
}

pub struct Index {
    by_id: crate::util::FxHashMap<u32, usize>,
}

impl Index {
    pub fn lookup(&self, id: u32) -> Option<usize> {
        self.by_id.get(&id).copied()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_use_std_collections() {
        let mut seen = std::collections::HashSet::new();
        assert!(seen.insert(1u32));
    }
}
