// Fixture: the same shapes as safety_violation.rs, each documented in
// one of the accepted styles. Expected: no violations.

pub struct W(*mut u8);

// SAFETY: W's pointer is only dereferenced by its owner.
unsafe impl Send for W {}

/// Reads the byte behind `p`.
///
/// # Safety
/// `p` must be valid for reads.
#[inline]
pub unsafe fn raw(p: *const u8) -> u8 {
    *p
}

pub fn caller(w: &W) -> u8 {
    // SAFETY: the constructor guarantees a live allocation.
    let a = unsafe { *w.0 };
    let b = unsafe { *w.0 }; // SAFETY: same-line form.
    a.wrapping_add(b)
}
