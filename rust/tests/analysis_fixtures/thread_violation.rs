// Fixture: raw thread creation outside par/pool.rs.
// Expected: 3 x thread-outside-pool (spawn, scope, Builder).

pub fn bad() {
    let h = std::thread::spawn(|| 1u32);
    h.join().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| 2u32);
    });
    let b = std::thread::Builder::new();
    drop(b);
}
