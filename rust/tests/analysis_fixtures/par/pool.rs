// Fixture: the one file allowed to create threads (matches the real
// tree's `par/pool.rs` exemption). Expected: no violations.

pub fn recruit() {
    let h = std::thread::Builder::new()
        .name("pdgrass-worker".into())
        .spawn(|| {})
        .unwrap();
    h.join().unwrap();
}
