// Fixture: every `unsafe` flavor without its SAFETY justification.
// Expected: 3 x safety-comment (block, impl, fn).

pub struct W(*mut u8);

unsafe impl Send for W {}

pub unsafe fn raw(p: *const u8) -> u8 {
    *p
}

pub fn caller(w: &W) -> u8 {
    unsafe { *w.0 }
}
