// Fixture: an allowlisted atomic ordering (see fixtures.allow), an
// aliased import, and `cmp::Ordering` variants that must not match.
// Expected: no violations.

use std::cmp::Ordering as CmpOrd;
use std::sync::atomic::{AtomicU64, Ordering as AtOrd};

pub static HITS: AtomicU64 = AtomicU64::new(0);

pub struct Counter;

impl Counter {
    pub fn bump(&self) {
        HITS.fetch_add(1, AtOrd::Relaxed);
    }
}

pub fn compare(a: u32, b: u32) -> CmpOrd {
    if a == b {
        CmpOrd::Equal
    } else {
        a.cmp(&b)
    }
}
