// Fixture: thread APIs that are fine anywhere, plus raw spawning
// confined to a `#[cfg(test)]` region. Expected: no violations.

pub fn fine() -> usize {
    std::thread::yield_now();
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_spawn() {
        let h = std::thread::spawn(|| 1u32);
        assert_eq!(h.join().unwrap(), 1);
        std::thread::scope(|s| {
            s.spawn(|| 2u32);
        });
    }
}
