// Fixture: an atomic ordering with no allowlist entry.
// Expected: 1 x atomic-allowlist (SeqCst in Counter::bump).

use std::sync::atomic::{AtomicU64, Ordering};

pub static HITS: AtomicU64 = AtomicU64::new(0);

pub struct Counter;

impl Counter {
    pub fn bump(&self) {
        HITS.fetch_add(1, Ordering::SeqCst);
    }
}
