//! Recovery property/golden suite: every parallel strategy — Serial,
//! Outer, Inner, Mixed, and the sharded giant-subtask path — must
//! recover the *bitwise identical* edge set at every thread count, on
//! randomized suite-family graphs and on the adversarial shapes the
//! paper's §V worst cases are built from (one giant LCA subtask,
//! all-singleton subtasks, zero off-tree edges).
//!
//! The recovery core is where correctness is subtlest (Lemma 8 forces
//! in-order commits; the sharded strategy reorders *work* but must never
//! reorder *decisions*), so these tests are deliberately exhaustive
//! across the strategy × thread-count grid.

use pdgrass::graph::Graph;
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::util::proptest::{check, Config};
use pdgrass::util::Rng;

const ALL_STRATEGIES: [Strategy; 5] = [
    Strategy::Serial,
    Strategy::Outer,
    Strategy::Inner,
    Strategy::Mixed,
    Strategy::Sharded,
];

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Test params: small cutoffs and shards so the inner-parallel and
/// sharded paths actually run on test-scale graphs (community-graph
/// subtasks of a few dozen edges must reach the large-subtask path and
/// split into several shards, or the grid would only exercise the
/// trivial small-subtask route).
fn params(alpha: f64, strategy: Strategy, threads: usize) -> Params {
    Params { strategy, cutoff_edges: 40, shard_min: 16, ..Params::new(alpha, threads) }
}

/// Assert that every (strategy, threads) combination reproduces the
/// serial single-thread recovery bitwise.
fn assert_all_agree(g: &Graph, alpha: f64, label: &str) {
    let sp = build_spanning(g);
    let base = recovery::pdgrass(g, &sp, &params(alpha, Strategy::Serial, 1));
    for strategy in ALL_STRATEGIES {
        for threads in THREAD_COUNTS {
            let r = recovery::pdgrass(g, &sp, &params(alpha, strategy, threads));
            assert_eq!(
                r.edges,
                base.edges,
                "{label}: {strategy:?} at {threads} threads diverged from serial"
            );
            assert_eq!(r.passes, base.passes, "{label}: {strategy:?} pass count diverged");
        }
    }
}

#[test]
fn all_strategies_bitwise_identical_on_random_graphs() {
    check(Config { cases: 6, base_seed: 0x5A }, "strategies_threads", |rng| {
        let g = pdgrass::gen::community(
            pdgrass::gen::CommunityParams {
                n: 400 + rng.below(400),
                mean_size: 10.0,
                tail: 1.7,
                intra_p: 0.5,
                bridges: 2,
                max_size: 80,
            },
            rng,
        );
        let sp = build_spanning(&g);
        let base = recovery::pdgrass(&g, &sp, &params(0.1, Strategy::Serial, 1));
        for strategy in ALL_STRATEGIES {
            for threads in THREAD_COUNTS {
                let r = recovery::pdgrass(&g, &sp, &params(0.1, strategy, threads));
                if r.edges != base.edges {
                    return Err(format!("{strategy:?} at {threads} threads diverged"));
                }
            }
        }
        Ok(())
    });
}

/// The feGRASS worst case: a star-like hub concentrates off-tree edge
/// LCAs in one giant subtask, the shape where Outer/Mixed degrade to a
/// single worker and Sharded must both split the work *and* stay exact.
#[test]
fn star_graph_forces_one_giant_subtask() {
    let g = pdgrass::gen::hub_graph(3000, 1, 2500, &mut Rng::new(7));
    let sp = build_spanning(&g);
    let base = recovery::pdgrass(&g, &sp, &params(0.2, Strategy::Serial, 1));
    assert!(
        base.stats.biggest_subtask > 64,
        "hub graph should yield a dominant subtask, got {}",
        base.stats.biggest_subtask
    );
    assert_all_agree(&g, 0.2, "star");
    // …and the giant subtask really was sharded, not serialized.
    let r = recovery::pdgrass(&g, &sp, &params(0.2, Strategy::Sharded, 8));
    assert!(r.stats.sharded_subtasks >= 1, "no subtask took the sharded path");
    assert!(r.stats.shards > 1, "giant subtask must split into multiple shards");
}

/// The opposite extreme: a complete binary tree of heavy edges plus one
/// light chord between each sibling pair. Every chord's LCA is its
/// parent, so every subtask is a singleton — no similarity, no marks,
/// and nothing for speculation to get wrong.
#[test]
fn all_singleton_subtasks() {
    let n = 511usize; // full binary tree: internal vertices 0..=254
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    for i in 0..n {
        for c in [2 * i + 1, 2 * i + 2] {
            if c < n {
                edges.push((i as u32, c as u32, 100.0));
            }
        }
    }
    let mut chords = 0usize;
    for i in 0..n {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        if r < n {
            // vary weights so scores aren't all tied
            edges.push((l as u32, r as u32, 0.5 + (i % 7) as f64 * 0.08));
            chords += 1;
        }
    }
    let g = Graph::from_edges(n, &edges);
    let sp = build_spanning(&g);
    // The heavy tree dominates every chord under the effective-weight
    // MST, so exactly the chords are off-tree…
    assert_eq!(sp.num_off_tree(), chords);
    // …and each has a distinct LCA (its sibling pair's parent).
    let base = recovery::pdgrass(&g, &sp, &params(0.2, Strategy::Serial, 1));
    assert_eq!(base.stats.biggest_subtask, 1);
    assert_eq!(base.stats.subtasks, chords);
    assert_all_agree(&g, 0.2, "singletons");
}

/// A pure tree has zero off-tree edges: recovery must return empty on
/// every strategy without touching a single pass.
#[test]
fn zero_off_tree_edges() {
    let n = 100usize;
    let edges: Vec<(u32, u32, f64)> =
        (0..n - 1).map(|i| (i as u32, i as u32 + 1, 1.0 + (i % 3) as f64)).collect();
    let g = Graph::from_edges(n, &edges);
    let sp = build_spanning(&g);
    assert_eq!(sp.num_off_tree(), 0);
    for strategy in ALL_STRATEGIES {
        for threads in THREAD_COUNTS {
            let r = recovery::pdgrass(&g, &sp, &params(0.5, strategy, threads));
            assert!(r.edges.is_empty(), "{strategy:?} recovered from a tree");
            assert_eq!(r.passes, 0, "{strategy:?} ran a pass over nothing");
        }
    }
}

/// Shard-merge accounting (regression): a sharded recovery counts each
/// judged edge exactly once in `Stats` and `CostTrace` — the commit is
/// the single authoritative pass — and none of the accounting depends on
/// the thread count, because shard shapes depend only on the subtask
/// size and `shard_min`.
#[test]
fn sharded_stats_and_trace_count_each_edge_once() {
    // Community graphs have real intra-subtask similarity (unlike a pure
    // hub star, whose LCA sits on an endpoint ⇒ β* = 0 ⇒ no marks), so
    // this exercises cross-shard marks, false positives, and commit
    // misses — the cases where double counting could creep in.
    let g = pdgrass::gen::community(
        pdgrass::gen::CommunityParams {
            n: 1500,
            mean_size: 10.0,
            tail: 1.7,
            intra_p: 0.5,
            bridges: 2,
            max_size: 80,
        },
        &mut Rng::new(11),
    );
    let sp = build_spanning(&g);
    let serial =
        recovery::pdgrass::pdgrass_traced(&g, &sp, &params(0.1, Strategy::Serial, 1), true);
    let sharded: Vec<recovery::Recovery> = THREAD_COUNTS
        .iter()
        .map(|&t| {
            recovery::pdgrass::pdgrass_traced(&g, &sp, &params(0.1, Strategy::Sharded, t), true)
        })
        .collect();
    for (r, &t) in sharded.iter().zip(&THREAD_COUNTS) {
        assert_eq!(r.edges, serial.edges, "threads={t}");
        // One trace entry per off-tree edge: shard merges never double- or
        // under-count a judged edge.
        let traced: usize = r.trace.as_ref().unwrap().subtask_costs.iter().map(|c| c.len()).sum();
        assert_eq!(traced, sp.num_off_tree(), "threads={t}");
        // The commit spine judges each edge exactly once (== serial), and
        // committed BFS work is bitwise the serial work (explore is pure).
        assert_eq!(r.stats.check_units, serial.stats.check_units, "threads={t}");
        assert_eq!(r.stats.bfs_units, serial.stats.bfs_units, "threads={t}");
        // Recovered edge ids are unique.
        let mut seen = std::collections::HashSet::new();
        assert!(r.edges.iter().all(|e| seen.insert(*e)), "threads={t}: duplicate edge");
    }
    // Full accounting — including wasted-speculation counters — is
    // thread-count invariant.
    for r in &sharded[1..] {
        assert_eq!(
            format!("{:?}", r.stats),
            format!("{:?}", sharded[0].stats),
            "sharded stats must not depend on thread count"
        );
        assert_eq!(
            r.trace.as_ref().unwrap().subtask_costs,
            sharded[0].trace.as_ref().unwrap().subtask_costs,
            "sharded cost trace must not depend on thread count"
        );
    }
}
