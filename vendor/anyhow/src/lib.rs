//! Offline subset of the `anyhow` crate.
//!
//! This environment has no registry access, so the repo vendors the small
//! slice of `anyhow` it actually uses: [`Error`], [`Result`], and the
//! [`anyhow!`], [`bail!`], [`ensure!`] macros. Semantics match upstream
//! for this subset:
//!
//! * `Error` wraps any `std::error::Error + Send + Sync + 'static` (so
//!   `?` works on io/parse/domain errors) or a formatted message;
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   which is what makes the blanket `From` impl coherent — the same
//!   trick upstream uses.

use std::fmt;

/// A type-erased error: either a wrapped source error or a message.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>`, the crate's ubiquitous alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Message-only error payload backing [`Error::msg`].
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

impl Error {
    /// Create an error from a plain message (what [`anyhow!`] expands to).
    pub fn msg(message: String) -> Error {
        Error { inner: Box::new(MessageError(message)) }
    }

    /// Downcast-free access to the chain root as `dyn Error`.
    pub fn as_dyn(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        &*self.inner
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Match upstream: Debug prints the display chain, which is what
        // `unwrap()` panics show.
        fmt::Display::fmt(&self.inner, f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

/// Construct an [`Error`] from a format string (captures allowed) or any
/// `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: {}",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $msg:literal $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($msg));
        }
    };
    ($cond:expr, $err:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($err));
        }
    };
    ($cond:expr, $fmt:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($fmt, $($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // ParseIntError -> Error via blanket From
        ensure!(n >= 0, "negative: {n}");
        if n > 100 {
            bail!("too big: {} > {}", n, 100);
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_macros() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        assert_eq!(parse("-3").unwrap_err().to_string(), "negative: -3");
        assert_eq!(parse("500").unwrap_err().to_string(), "too big: 500 > 100");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("captured {x}");
        assert_eq!(b.to_string(), "captured 7");
        let c = anyhow!("fmt {} {}", 1, 2);
        assert_eq!(c.to_string(), "fmt 1 2");
        let io = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        let d = anyhow!(io);
        assert_eq!(d.to_string(), "disk on fire");
    }

    #[test]
    fn debug_is_display() {
        let e = anyhow!("shown");
        assert_eq!(format!("{e:?}"), "shown");
    }
}
