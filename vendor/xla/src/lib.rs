//! Stub of the `xla` crate (PJRT C-API bindings).
//!
//! The real crate needs the PJRT CPU plugin shared object, which is not
//! present in this offline build environment. This stub mirrors the API
//! surface `pdgrass::runtime` consumes so the crate compiles and the
//! pure-Rust paths run; every entry point that would touch PJRT returns
//! [`Error`] at runtime instead. The XLA-path tests and examples detect
//! the missing artifacts/client and skip themselves.
//!
//! Swap this path dependency for the real `xla` crate to light up the
//! compiled-kernel path; no call-site changes are needed.

/// Error type matching the real crate's `Debug`-formatted errors.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("{what}: PJRT runtime unavailable (offline `xla` stub; link the real crate)"))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a literal to a device buffer.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }

    /// Upload a host slice to a device buffer with the given dimensions.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with device-buffer arguments.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }

    /// Execute with literal arguments.
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronous readback into a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub; carries no data).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a 1-tuple result.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Destructure a 2-tuple result.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("PJRT runtime unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
