//! The skewed-input worst case — §I's com-Youtube pathology, end to end.
//!
//! ```bash
//! cargo run --release --example social_network
//! ```
//!
//! On hub-dominated social graphs, feGRASS's loose vertex-cover condition
//! collapses: covering one hub marks almost every off-tree edge similar,
//! so each pass recovers a handful of edges and the pass count explodes
//! (>6000 in the paper, >100000 at α=0.10). pdGRASS's strict condition
//! recovers everything in ONE pass, and its giant single subtask is
//! handled by the inner-parallel strategy with Judge-before-Parallel.
//! This example measures both, prints the Table III-style JBP statistics,
//! and sanity-checks the sparsifier quality.

use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::tree::build_spanning;
use pdgrass::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let g = pdgrass::gen::rmat(14, 8.0, pdgrass::gen::RmatParams::youtube_like(), &mut Rng::new(9));
    let (g, _) = pdgrass::graph::largest_component(&g);
    println!(
        "social graph: |V|={} |E|={} max-degree={} (avg {:.1})",
        g.num_vertices(),
        g.num_edges(),
        g.max_degree(),
        g.avg_degree()
    );
    let sp = build_spanning(&g);

    for alpha in [0.02, 0.05, 0.10] {
        let params = Params::new(alpha, 8);
        let t = Timer::start();
        let fe = recovery::fegrass(&g, &sp, &params);
        let t_fe = t.ms();
        let t = Timer::start();
        let pd = recovery::pdgrass(&g, &sp, &params);
        let t_pd = t.ms();
        println!(
            "α={alpha:4}: feGRASS {:6} passes / {:8.1} ms   pdGRASS {} pass / {:8.1} ms  ({} edges each)",
            fe.passes, t_fe, pd.passes, t_pd, pd.edges.len()
        );
        anyhow::ensure!(pd.passes == 1, "pdGRASS must finish in one pass");
        anyhow::ensure!(fe.passes > pd.passes, "skewed input must hurt feGRASS");
    }

    // Judge-before-Parallel statistics on the biggest subtask (Table III).
    let mut params = Params::new(0.02, 32);
    params.strategy = Strategy::Inner;
    params.block = 32;
    params.jbp = false;
    let without = recovery::pdgrass(&g, &sp, &params).stats;
    params.jbp = true;
    let with = recovery::pdgrass(&g, &sp, &params).stats;
    println!("\nJudge-before-Parallel on the biggest subtask ({} edges):", with.biggest_subtask);
    println!(
        "  without: {} blocked edges, {} skipped in parallel ({:.0}%), {} false positives",
        without.edges_in_blocks,
        without.skipped_in_parallel,
        100.0 * without.skipped_in_parallel as f64 / without.edges_in_blocks.max(1) as f64,
        without.false_positives
    );
    println!(
        "  with:    {} blocked edges, {} skipped in parallel, {} false positives",
        with.edges_in_blocks, with.skipped_in_parallel, with.false_positives
    );
    anyhow::ensure!(with.skipped_in_parallel == 0, "JBP must eliminate bubbles");

    println!("\nsocial_network OK");
    Ok(())
}
