//! Power-grid transient analysis — the application domain that motivated
//! feGRASS (power grid analysis, TCAD'21) and pGRASS-Solver (ICCAD'21).
//!
//! ```bash
//! cargo run --release --example power_grid
//! ```
//!
//! Scenario: a large resistive power-delivery network must be solved for
//! many right-hand sides (one per transient time step, current loads
//! changing each step). We sparsify once with pdGRASS, factor the
//! sparsifier once, and reuse it as the PCG preconditioner across all
//! steps — amortizing the sparsification exactly as the power-grid
//! solvers built on GRASS do. Reported: total solve time and iteration
//! counts vs an unpreconditioned/Jacobi baseline.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::{self, Params};
use pdgrass::solver::{pcg, Jacobi, SparsifierPrecond};
use pdgrass::tree::build_spanning;
use pdgrass::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    // A power grid is mesh-like: a 2-D grid of rails with vias (random
    // diagonals) and widely varying metal conductances.
    let mut rng = Rng::new(7);
    let g = pdgrass::gen::grid(150, 150, 0.25, &mut rng);
    let n = g.num_vertices();
    println!("power grid: |V|={} |E|={}", n, g.num_edges());

    // --- one-time setup: sparsify + factor ---
    let t_setup = Timer::start();
    let sp = build_spanning(&g);
    let params = Params::new(0.05, 4);
    let rec = recovery::pdgrass(&g, &sp, &params);
    let p = recovery::sparsifier(&g, &sp, &rec.edges);
    let m = SparsifierPrecond::new(&p)?;
    let setup_ms = t_setup.ms();
    println!(
        "setup: sparsifier {} edges (α={}), LDLᵀ fill nnz(L)={}, {:.1} ms",
        p.num_edges(),
        params.alpha,
        m.nnz_l(),
        setup_ms
    );

    let lg = grounded_laplacian(&g, 0);
    let jacobi = Jacobi::new(&lg)?;

    // --- transient loop: 20 time steps, loads drift each step ---
    let steps = 20;
    let mut load: Vec<f64> = (0..lg.n).map(|_| rng.normal().abs() * 0.1).collect();
    let (mut it_pd, mut it_jac) = (0usize, 0usize);
    let mut t_pd = 0.0;
    let mut t_jac = 0.0;
    for _ in 0..steps {
        // loads drift (a few blocks switch)
        for _ in 0..lg.n / 50 {
            let i = rng.below(lg.n);
            load[i] = rng.normal().abs();
        }
        let t = Timer::start();
        let r1 = pcg(&lg, &load, &m, 1e-3, 50_000);
        t_pd += t.ms();
        let t = Timer::start();
        let r2 = pcg(&lg, &load, &jacobi, 1e-3, 50_000);
        t_jac += t.ms();
        anyhow::ensure!(r1.converged && r2.converged, "solver failed to converge");
        it_pd += r1.iterations;
        it_jac += r2.iterations;
    }
    println!("\n{steps} transient steps, tol 1e-3:");
    println!(
        "  pdGRASS-preconditioned: {:6} total iters, {:8.1} ms (+{:.1} ms setup)",
        it_pd, t_pd, setup_ms
    );
    println!("  Jacobi baseline:        {:6} total iters, {:8.1} ms", it_jac, t_jac);
    println!(
        "  speedup (solve-only): {:.2}×, iters ratio {:.1}×",
        t_jac / t_pd,
        it_jac as f64 / it_pd as f64
    );
    anyhow::ensure!(it_pd < it_jac, "sparsifier preconditioner should beat Jacobi");
    println!("\npower_grid OK");
    Ok(())
}
