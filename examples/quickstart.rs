//! Quickstart: sparsify a graph with pdGRASS and use the sparsifier as a
//! PCG preconditioner.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface: generator → spanning tree →
//! pdGRASS recovery → sparsifier assembly → PCG quality comparison
//! against the feGRASS baseline, the tree-only preconditioner, and
//! Jacobi.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::solver::{pcg, Jacobi, SparsifierPrecond};
use pdgrass::tree::build_spanning;
use pdgrass::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    // 1. A graph. Any `graph::Graph` works (MatrixMarket via
    //    `graph::read_mtx`, or a generator). Here: a 120×120 grid with
    //    diagonals — a small census-style instance.
    let g = pdgrass::gen::grid(120, 120, 0.4, &mut Rng::new(1));
    println!("graph: |V|={} |E|={}", g.num_vertices(), g.num_edges());

    // 2. Spanning tree on effective weights (shared by both algorithms).
    let sp = build_spanning(&g);

    // 3. Recover α|V| off-tree edges with pdGRASS (mixed parallel
    //    strategy) and with the feGRASS baseline.
    let params = Params { strategy: Strategy::Mixed, ..Params::new(0.05, 4) };
    let t = Timer::start();
    let pd = recovery::pdgrass(&g, &sp, &params);
    let t_pd = t.ms();
    let t = Timer::start();
    let fe = recovery::fegrass(&g, &sp, &params);
    let t_fe = t.ms();
    println!(
        "pdGRASS: {} edges in {} pass(es), {:.1} ms   |   feGRASS: {} edges in {} pass(es), {:.1} ms",
        pd.edges.len(),
        pd.passes,
        t_pd,
        fe.edges.len(),
        fe.passes,
        t_fe
    );

    // 4. Assemble sparsifiers: tree + recovered edges.
    let p_pd = recovery::sparsifier(&g, &sp, &pd.edges);
    let p_fe = recovery::sparsifier(&g, &sp, &fe.edges);
    let p_tree = recovery::sparsifier(&g, &sp, &[]);

    // 5. PCG on the grounded Laplacian system L_G x = b with each
    //    preconditioner — lower iteration count = better sparsifier.
    let lg = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(2);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let tol = 1e-3;
    let runs = [
        ("pdGRASS sparsifier", pcg(&lg, &b, &SparsifierPrecond::new(&p_pd)?, tol, 50_000)),
        ("feGRASS sparsifier", pcg(&lg, &b, &SparsifierPrecond::new(&p_fe)?, tol, 50_000)),
        ("spanning tree only", pcg(&lg, &b, &SparsifierPrecond::new(&p_tree)?, tol, 50_000)),
        ("Jacobi (diagonal)", pcg(&lg, &b, &Jacobi::new(&lg), tol, 50_000)),
    ];
    println!("\nPCG to ‖r‖ ≤ 1e-3‖b‖:");
    for (name, res) in &runs {
        println!(
            "  {name:22} {:5} iterations (converged={})",
            res.iterations, res.converged
        );
    }
    let (pd_it, tree_it) = (runs[0].1.iterations, runs[2].1.iterations);
    anyhow::ensure!(pd_it < tree_it, "recovered edges must improve on the bare tree");
    println!("\nquickstart OK");
    Ok(())
}
