//! Quickstart: sparsify a graph with pdGRASS and use the sparsifier as a
//! PCG preconditioner.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the primary (session) API surface — `Sparsify → Prepared →
//! recover → Sparsifier → pcg` — plus the low-level building blocks for
//! the tree-only and Jacobi baselines. Steps 1–3 of Algorithm 1 run once
//! in `prepare()`; both the pdGRASS and feGRASS recoveries reuse them.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery;
use pdgrass::solver::{pcg, Jacobi, SparsifierPrecond};
use pdgrass::util::{Rng, Timer};
use pdgrass::{RecoverOpts, Sparsify};

fn main() -> anyhow::Result<()> {
    // 1. A graph. Any `graph::Graph` works (MatrixMarket via
    //    `graph::read_mtx`, or a generator). Here: a 120×120 grid with
    //    diagonals — a small census-style instance.
    let g = pdgrass::gen::grid(120, 120, 0.4, &mut Rng::new(1));
    println!("graph: |V|={} |E|={}", g.num_vertices(), g.num_edges());

    // 2. Prepare once: spanning tree on effective weights, resistance
    //    scoring, criticality sort (steps 1–3, shared by every recovery).
    let prepared = Sparsify::graph(g).named("census-grid").prepare()?;

    // 3. Recover α|V| off-tree edges with pdGRASS (mixed parallel
    //    strategy) and with the feGRASS baseline — both from the same
    //    prepared session, paying only step 4 each.
    let opts = RecoverOpts::new(0.05);
    let t = Timer::start();
    let pd = prepared.recover(&opts)?;
    let t_pd = t.ms();
    let t = Timer::start();
    let fe = prepared.fegrass(&opts)?;
    let t_fe = t.ms();
    println!(
        "pdGRASS: {} edges in {} pass(es), {:.1} ms   |   feGRASS: {} edges in {} pass(es), {:.1} ms",
        pd.edges().len(),
        pd.passes(),
        t_pd,
        fe.edges().len(),
        fe.passes(),
        t_fe
    );

    // 4. Sparsifier handles: tree + recovered edges.
    let p_pd = pd.sparsifier();
    let p_fe = fe.sparsifier();

    // 5. PCG on the grounded Laplacian system L_G x = b with each
    //    preconditioner — lower iteration count = better sparsifier. The
    //    session handles evaluate themselves; the tree-only and Jacobi
    //    baselines use the low-level solver API with the same RHS.
    let tol = 1e-3;
    let r_pd = p_pd.pcg(2, tol, 50_000)?;
    let r_fe = p_fe.pcg(2, tol, 50_000)?;
    let p_tree = recovery::sparsifier(prepared.graph(), prepared.spanning(), &[]);
    let lg = grounded_laplacian(prepared.graph(), 0);
    let mut rng = Rng::new(2);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let r_tree = pcg(&lg, &b, &SparsifierPrecond::new(&p_tree)?, tol, 50_000);
    let r_jac = pcg(&lg, &b, &Jacobi::new(&lg)?, tol, 50_000);
    println!("\nPCG to ‖r‖ ≤ 1e-3‖b‖:");
    for (name, iters, converged) in [
        ("pdGRASS sparsifier", r_pd.iterations, r_pd.converged),
        ("feGRASS sparsifier", r_fe.iterations, r_fe.converged),
        ("spanning tree only", r_tree.iterations, r_tree.converged),
        ("Jacobi (diagonal)", r_jac.iterations, r_jac.converged),
    ] {
        println!("  {name:22} {iters:5} iterations (converged={converged})");
    }
    anyhow::ensure!(
        r_pd.iterations < r_tree.iterations,
        "recovered edges must improve on the bare tree"
    );
    println!("\nquickstart OK");
    Ok(())
}
