//! End-to-end three-layer driver — proves all layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_pcg
//! ```
//!
//! Layer 3 (this Rust binary) builds suite graphs, runs pdGRASS, factors
//! the sparsifier preconditioner, and drives PCG; every `L_G·p` of the
//! hot loop executes the **AOT-compiled Pallas ELL kernel** (Layer 1,
//! authored in `python/compile/kernels/spmv_ell.py`, lowered through the
//! Layer-2 jax graph by `python/compile/aot.py`) on the PJRT CPU client.
//! Python is not running — only its compiled HLO artifacts are.
//!
//! Reports the paper's headline metric (PCG iteration count) measured on
//! the XLA path, cross-checked against the pure-Rust path, plus dispatch
//! timing.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::{self, Params};
use pdgrass::runtime::{jacobi_pcg_xla, pcg_xla, Runtime};
use pdgrass::solver::{pcg, SparsifierPrecond};
use pdgrass::tree::build_spanning;
use pdgrass::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    println!("runtime: {} artifacts loaded from manifest", rt.manifest().len());

    println!(
        "\n{:<16} {:>6} {:>8} {:>10} {:>10} {:>12} {:>12}",
        "graph", "|V|", "|E|", "iters-rust", "iters-xla", "t-rust(ms)", "t-xla(ms)"
    );
    for name in ["01-mi2010", "15-M6", "09-com-Youtube"] {
        // scale 0.25 keeps the grounded system inside the 16384/65536
        // buckets and the demo under a minute
        let g = pdgrass::gen::suite::build(name, 0.25, pdgrass::gen::DEFAULT_SEED);
        let sp = build_spanning(&g);
        let rec = recovery::pdgrass(&g, &sp, &Params::new(0.05, 4));
        let p = recovery::sparsifier(&g, &sp, &rec.edges);
        let lg = grounded_laplacian(&g, 0);
        let m = SparsifierPrecond::new(&p)?;
        let mut rng = Rng::new(0xE2E);
        let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();

        let t = Timer::start();
        let rust = pcg(&lg, &b, &m, 1e-3, 50_000);
        let t_rust = t.ms();
        let t = Timer::start();
        let xla = pcg_xla(&rt, &lg, &b, &m, 1e-3, 50_000)?;
        let t_xla = t.ms();
        anyhow::ensure!(rust.converged && xla.converged, "{name}: PCG diverged");
        println!(
            "{:<16} {:>6} {:>8} {:>10} {:>10} {:>12.1} {:>12.1}",
            name,
            g.num_vertices(),
            g.num_edges(),
            rust.iterations,
            xla.iterations,
            t_rust,
            t_xla
        );
        let diff = (rust.iterations as i64 - xla.iterations as i64).unsigned_abs() as usize;
        anyhow::ensure!(
            diff <= rust.iterations / 10 + 3,
            "{name}: XLA path iteration count diverged ({} vs {})",
            rust.iterations,
            xla.iterations
        );
    }

    // Fully-fused path: one PJRT dispatch = 200 scan-fused PCG iterations.
    let g = pdgrass::gen::grid(32, 32, 0.4, &mut Rng::new(3));
    let lg = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(4);
    let b: Vec<f64> = (0..lg.n).map(|_| rng.normal()).collect();
    let t = Timer::start();
    let (_, hist) = jacobi_pcg_xla(&rt, &lg, &b)?;
    let one_dispatch_ms = t.ms();
    let iters = pdgrass::runtime::iterations_to_tol(&hist, 1e-3);
    println!(
        "\nscan-fused jacobi_pcg (n-bucket dispatch): {iters:?} iterations to 1e-3 \
         in ONE dispatch, {one_dispatch_ms:.1} ms total"
    );
    anyhow::ensure!(iters.is_some(), "fused path must converge");

    println!("\nxla_pcg OK — all three layers compose");
    Ok(())
}
