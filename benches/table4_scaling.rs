//! Regenerates **Table IV**: feGRASS vs pdGRASS runtimes at 1/8/32
//! threads, α = 0.02 (T₁ measured; T₈/T₃₂ from the calibrated scheduling
//! simulator, `coordinator::schedsim`).
//!
//! `cargo bench --bench table4_scaling`

use pdgrass::coordinator::{experiments, PipelineConfig};

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PipelineConfig { scale, trials: 3, ..Default::default() };
    println!("# Table IV bench — 1/8/32-thread runtimes (scale={scale})");
    let reports = experiments::table4(&experiments::suite_names(), &cfg);
    // Paper shape: pdGRASS-32 beats feGRASS on every row; average parallel
    // speedup grows with threads.
    let avg8: f64 =
        reports.iter().map(|r| r.sim_speedup[0]).sum::<f64>() / reports.len() as f64;
    let avg32: f64 =
        reports.iter().map(|r| r.sim_speedup[1]).sum::<f64>() / reports.len() as f64;
    assert!(avg32 > avg8, "32-thread speedup ({avg32:.1}) must exceed 8-thread ({avg8:.1})");
    let wins = reports
        .iter()
        .filter(|r| r.t_fe_ms / r.t_pd_sim_ms[1] > 1.0)
        .count();
    println!("\npdGRASS-32 faster than feGRASS on {wins}/{} rows", reports.len());
    println!("# table4_scaling done");
}
