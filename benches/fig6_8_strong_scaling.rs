//! Regenerates **Figures 6–8**: strong-scaling curves on the uniform (M6)
//! and skewed (com-Youtube) representatives — entire-outer, inner-part and
//! outer-part speedups for p ∈ {1, 2, 4, 8, 16, 32} (CSV series).
//!
//! `cargo bench --bench fig6_8_strong_scaling`

use pdgrass::coordinator::{experiments, PipelineConfig};

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PipelineConfig { scale, ..Default::default() };
    println!("# Figs. 6–8 bench — strong scaling (scale={scale})");
    let curves = experiments::fig6_7_8(&cfg);
    let at = |label_prefix: &str, p: usize| -> f64 {
        curves
            .iter()
            .find(|(l, _)| l.starts_with(label_prefix))
            .and_then(|(_, pts)| pts.iter().find(|(t, _)| *t == p))
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    };
    // Paper shapes: Fig. 6 near-ideal outer scaling on the uniform mesh;
    // Fig. 7 inner part keeps climbing; Fig. 8 outer part plateaus early.
    let f6 = at("fig6", 32);
    let f7_32 = at("fig7", 32);
    let f7_4 = at("fig7", 4);
    let f8_2 = at("fig8", 2);
    let f8_32 = at("fig8", 32);
    println!("# fig6@32={f6:.1} fig7@32={f7_32:.1} fig8@2={f8_2:.1} fig8@32={f8_32:.1}");
    assert!(f6 > 8.0, "uniform M6 outer scaling too weak: {f6:.1}");
    assert!(f7_32 > f7_4, "inner part must keep scaling");
    assert!(
        f8_32 < f6,
        "skewed outer part ({f8_32:.1}) must scale worse than uniform ({f6:.1})"
    );
    println!("# fig6_8_strong_scaling done");
}
