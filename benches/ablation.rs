//! Ablation benches for the repo's load-bearing design choices:
//!
//! 1. strict vs loose similarity — pass count + quality at equal budgets;
//! 2. β cap `c` sweep — recovery behaviour vs the neighborhood radius;
//! 3. block size sweep — simulated inner-parallel time;
//! 4. Judge-before-Parallel on/off — simulated time on the skewed input;
//! 5. ELL width k sweep — padding vs COO-tail trade-off.
//!
//! `cargo bench --bench ablation`

use pdgrass::coordinator::schedsim::{simulate, SimParams};
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::runtime::EllMatrix;
use pdgrass::tree::build_spanning;
use pdgrass::util::Table;

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);

    // --- 1. strict vs loose at equal edge budgets ---
    println!("# ablation 1: strict (pdGRASS) vs loose (feGRASS) condition, scale={scale}");
    let mut t = Table::new(&["graph", "alpha", "fe_passes", "pd_passes", "iter_fe", "iter_pd"]);
    for name in ["06-tx2010", "09-com-Youtube", "12-coAuthorsDBLP"] {
        let g = pdgrass::gen::suite::build(name, scale, 3);
        let sp = build_spanning(&g);
        for alpha in [0.02, 0.10] {
            let params = Params::new(alpha, 1);
            let fe = recovery::fegrass(&g, &sp, &params);
            let pd = recovery::pdgrass(&g, &sp, &params);
            let pfe = recovery::sparsifier(&g, &sp, &fe.edges);
            let ppd = recovery::sparsifier(&g, &sp, &pd.edges);
            let (ife, _) = pdgrass::solver::pcg_iterations(&g, &pfe, 7, 1e-3, 50_000).unwrap();
            let (ipd, _) = pdgrass::solver::pcg_iterations(&g, &ppd, 7, 1e-3, 50_000).unwrap();
            t.row(vec![
                name.into(),
                format!("{alpha}"),
                fe.passes.to_string(),
                pd.passes.to_string(),
                ife.to_string(),
                ipd.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    // --- 2. β cap sweep ---
    println!("# ablation 2: beta cap sweep (09-com-Youtube, alpha=0.05)");
    let g = pdgrass::gen::suite::build("09-com-Youtube", scale, 3);
    let sp = build_spanning(&g);
    let mut t = Table::new(&["beta_cap", "passes", "recovered", "check_units", "bfs_units"]);
    for cap in [0u32, 1, 2, 4, 8, 16] {
        let params = Params { beta_cap: cap, ..Params::new(0.05, 1) };
        let r = recovery::pdgrass(&g, &sp, &params);
        t.row(vec![
            cap.to_string(),
            r.passes.to_string(),
            r.edges.len().to_string(),
            r.stats.check_units.to_string(),
            r.stats.bfs_units.to_string(),
        ]);
    }
    println!("{}", t.render());

    // --- 3+4. block size & JBP: simulated inner time on the skewed input ---
    println!("# ablation 3/4: block size × JBP (simulated units, 32 threads)");
    let params = Params { strategy: Strategy::Serial, ..Params::new(0.05, 1) };
    let r = recovery::pdgrass::pdgrass_traced(&g, &sp, &params, true);
    let trace = r.trace.unwrap();
    let mut t = Table::new(&["block", "jbp", "sim_time_units", "speedup"]);
    for block in [8usize, 16, 32, 64, 128] {
        for jbp in [true, false] {
            let mut sp_ = SimParams::new(32);
            sp_.block = block;
            sp_.jbp = jbp;
            sp_.cutoff_frac = 0.10;
            let sim = simulate(&trace, &sp_);
            t.row(vec![
                block.to_string(),
                jbp.to_string(),
                sim.time().to_string(),
                format!("{:.2}", sim.speedup()),
            ]);
        }
    }
    println!("{}", t.render());

    // --- 5. ELL width sweep ---
    println!("# ablation 5: ELL width k — padding vs COO tail (grounded L_G)");
    let a = pdgrass::graph::grounded_laplacian(&g, 0);
    let nb = pdgrass::runtime::pick_n_bucket(a.n).unwrap_or(1 << 16);
    let mut t = Table::new(&["k", "padding_%", "tail_entries", "ell_bytes"]);
    for k in [4usize, 8, 16, 32, 64] {
        let e = EllMatrix::from_csr(&a, nb, k);
        t.row(vec![
            k.to_string(),
            format!("{:.1}", 100.0 * e.padding_ratio()),
            e.tail.len().to_string(),
            (e.values.len() * 4 + e.indices.len() * 4).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("# ablation done");
}
