//! Regenerates **Figure 1**: the relative-runtime vs relative-quality
//! scatter over the 18-graph suite × α ∈ {0.02, 0.05, 0.10} (CSV).
//!
//! `cargo bench --bench fig1_scatter`

use pdgrass::coordinator::{experiments, PipelineConfig};

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PipelineConfig { scale, trials: 1, ..Default::default() };
    println!("# Fig. 1 bench — scatter CSV (scale={scale})");
    let pts = experiments::fig1(&experiments::suite_names(), &[0.02, 0.05, 0.10], &cfg);
    // Paper shape: as α grows the cloud drifts up-right — mean relative
    // iteration ratio increases with α.
    let mean_ratio = |a: f64| -> f64 {
        let v: Vec<f64> = pts
            .iter()
            .filter(|(_, alpha, _, ri)| *alpha == a && ri.is_finite())
            .map(|(_, _, _, ri)| *ri)
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let (r02, r10) = (mean_ratio(0.02), mean_ratio(0.10));
    println!("# mean iter ratio: alpha=0.02 → {r02:.2}, alpha=0.10 → {r10:.2}");
    assert!(
        r10 > r02,
        "quality advantage must grow with alpha ({r02:.2} → {r10:.2})"
    );
    println!("# fig1_scatter done");
}
