//! Regenerates **Table II**: recovery runtime + sparsifier quality for all
//! 18 suite graphs at α ∈ {0.02, 0.05, 0.10}.
//!
//! `cargo bench --bench table2_main`
//!
//! Environment knobs: `PDGRASS_BENCH_SCALE` (default 1.0),
//! `PDGRASS_BENCH_ALPHAS` (comma list), `PDGRASS_BENCH_GRAPHS`
//! (comma list of suite rows).

use pdgrass::coordinator::{experiments, PipelineConfig};

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let alphas: Vec<f64> = std::env::var("PDGRASS_BENCH_ALPHAS")
        .map(|s| s.split(',').filter_map(|a| a.trim().parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.02, 0.05, 0.10]);
    let names_own: Vec<String> = std::env::var("PDGRASS_BENCH_GRAPHS")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_default();
    let names: Vec<&str> = if names_own.is_empty() {
        experiments::suite_names()
    } else {
        names_own.iter().map(|s| s.as_str()).collect()
    };
    let cfg = PipelineConfig { scale, trials: 3, ..Default::default() };
    println!("# Table II bench — scale={scale}, 18-row suite (paper: Table II)");
    let all = experiments::table2(&names, &alphas, &cfg);
    // Shape assertions mirroring the paper's headline claims.
    for (alpha, reports) in &all {
        let single_pass = reports.iter().all(|r| r.pd_passes == 1);
        assert!(single_pass, "alpha={alpha}: pdGRASS must be single-pass on the suite");
    }
    println!("\n# table2_main done");
}
