//! Micro-benchmarks of the hot paths (the §Perf profiling surface).
//!
//! `cargo bench --bench micro`
//!
//! Measures, with min-of-N timing: LCA queries, resistance annotation,
//! β-hop neighborhood BFS, tag-store probes, CSR vs XLA SpMV, LDLᵀ
//! factor+solve, and the recovery phases. These numbers drive the
//! before/after entries in EXPERIMENTS.md §Perf.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::strict::{neighborhoods, TagStore};
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::solver::{spmv, LdlFactor, SparsifierPrecond};
use pdgrass::tree::{build_spanning, off_tree_edges};
use pdgrass::util::{min_of, Rng};
use std::sync::atomic::{AtomicUsize, Ordering};

fn report(name: &str, iters: usize, ms: f64, unit_count: u64, unit: &str) {
    let per = ms * 1e6 / unit_count.max(1) as f64;
    println!("{name:<38} {ms:>9.2} ms / {iters} it   ({per:>8.1} ns/{unit})");
}

/// The pre-pool `par_for`: spawn + join fresh scoped threads on every
/// call. Kept here (only here) as the baseline for the dispatch-cost
/// comparison — the library's `par::par_for` now runs on the persistent
/// pool and must beat this on small-n hot loops.
fn spawn_per_call_for<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Dispatch-overhead comparison: many small parallel loops, the shape of
/// `spmv_par` inside PCG (one small `par_for` per iteration, thousands
/// of iterations per solve).
fn bench_dispatch() {
    let threads = 4usize;
    let calls = 200usize;
    for n in [256usize, 4096] {
        let mut out = vec![0f64; n];
        let grain = (n / (4 * threads)).max(1);
        let (_, ms_spawn) = min_of(5, || {
            for _ in 0..calls {
                let ptr = SendCell(out.as_mut_ptr());
                spawn_per_call_for(n, threads, grain, |i| unsafe {
                    *ptr.0.add(i) = (i as f64).sqrt();
                });
            }
        });
        let (_, ms_pool) = min_of(5, || {
            for _ in 0..calls {
                let ptr = SendCell(out.as_mut_ptr());
                pdgrass::par::par_for(n, threads, grain, |i| unsafe {
                    *ptr.0.add(i) = (i as f64).sqrt();
                });
            }
        });
        report(&format!("par_for_dispatch_spawn(n={n})"), 5, ms_spawn, calls as u64, "call");
        report(&format!("par_for_dispatch_pool(n={n})"), 5, ms_pool, calls as u64, "call");
        println!(
            "{:<38} pooled dispatch {:.2}x vs spawn-per-call",
            "",
            ms_spawn / ms_pool.max(1e-9)
        );
    }
}

/// Raw-pointer cell for the disjoint-index writes in `bench_dispatch`.
struct SendCell(*mut f64);
unsafe impl Send for SendCell {}
unsafe impl Sync for SendCell {}

fn main() {
    println!("# micro bench: parallel-substrate dispatch cost (spawn vs persistent pool)");
    bench_dispatch();

    let g = pdgrass::gen::suite::build("15-M6", 0.5, 42);
    println!("# micro bench on 15-M6@0.5: |V|={} |E|={}", g.num_vertices(), g.num_edges());
    let sp = build_spanning(&g);

    // LCA queries
    let off_ids: Vec<(u32, u32)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
    let (_, ms) = min_of(5, || {
        let mut acc = 0u64;
        for &(u, v) in &off_ids {
            acc = acc.wrapping_add(sp.skip.lca(u, v) as u64);
        }
        acc
    });
    report("lca_query", 5, ms, off_ids.len() as u64, "query");

    // Resistance annotation (step 1)
    let (off, ms) = min_of(5, || off_tree_edges(&g, &sp));
    report("off_tree_annotation", 5, ms, off.len() as u64, "edge");

    // Neighborhood BFS at the recovery's β*
    let sample: Vec<_> = off.iter().take(20_000).collect();
    let (units, ms) = min_of(5, || {
        let mut acc = 0u64;
        for e in &sample {
            let (_, _, c) = neighborhoods(&sp, e, 8);
            acc += c as u64;
        }
        acc
    });
    report("neighborhood_bfs(beta*<=8)", 5, ms, units, "visit");

    // Tag-store probe throughput
    let mut ts = TagStore::new();
    let mut rng = Rng::new(1);
    for k in 0..2000u32 {
        let su: Vec<u32> = (0..8).map(|_| rng.next_u32() % 100_000).collect();
        let sv: Vec<u32> = (0..8).map(|_| rng.next_u32() % 100_000).collect();
        ts.add(k, &su, &sv);
    }
    let probes: Vec<(u32, u32)> =
        (0..200_000).map(|_| (rng.next_u32() % 100_000, rng.next_u32() % 100_000)).collect();
    let (_, ms) = min_of(5, || {
        let mut cost = 0u32;
        let mut hits = 0u64;
        for &(u, v) in &probes {
            if ts.is_similar(u, v, &mut cost) {
                hits += 1;
            }
        }
        hits
    });
    report("tagstore_probe", 5, ms, probes.len() as u64, "probe");

    // Recovery end to end (serial vs mixed)
    for (label, strat) in [("recovery_serial", Strategy::Serial), ("recovery_mixed", Strategy::Mixed)] {
        let params = Params { strategy: strat, cutoff_edges: 10_000, ..Params::new(0.05, 4) };
        let (_, ms) = min_of(3, || recovery::pdgrass(&g, &sp, &params));
        report(label, 3, ms, off.len() as u64, "edge");
    }

    // SpMV: CSR f64 (serial + 4-thread "parallel" on this 1-core box)
    let a = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(2);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let mut y = vec![0.0; a.n];
    let (_, ms) = min_of(10, || spmv(&a, &x, &mut y));
    report("spmv_csr_f64", 10, ms, a.nnz() as u64, "nnz");

    // LDL factor + solve on a sparsifier
    let r = recovery::pdgrass(&g, &sp, &Params::new(0.05, 1));
    let p = recovery::sparsifier(&g, &sp, &r.edges);
    let lp = grounded_laplacian(&p, 0);
    let (m, ms) = min_of(3, || SparsifierPrecond::from_matrix(&lp).unwrap());
    report("ldl_factor(rcm)", 3, ms, lp.nnz() as u64, "nnz");
    println!("{:<38} fill nnz(L) = {}", "", m.nnz_l());
    let ap = pdgrass::solver::rcm(&lp);
    let lp_p = pdgrass::solver::permute_sym(&lp, &ap);
    let f = LdlFactor::factor(&lp_p).unwrap();
    let mut z = x[..lp.n].to_vec();
    let (_, ms) = min_of(10, || {
        z.copy_from_slice(&x[..lp.n]);
        f.solve(&mut z);
    });
    report("ldl_solve", 10, ms, f.nnz_l() as u64, "nnz");

    // XLA SpMV dispatch (if artifacts are present)
    match pdgrass::runtime::Runtime::open_default() {
        Ok(rt) => match pdgrass::runtime::prepare_spmv(&rt, &a) {
            Ok(xs) => {
                let mut yx = vec![0.0; a.n];
                let (_, ms) = min_of(10, || xs.apply(&x, &mut yx).unwrap());
                report("spmv_xla_dispatch", 10, ms, a.nnz() as u64, "nnz");
                println!(
                    "{:<38} bucket n={} k={} pad={:.0}% tail={}",
                    "",
                    xs.ell.n_bucket,
                    xs.ell.k,
                    100.0 * xs.ell.padding_ratio(),
                    xs.ell.tail.len()
                );
            }
            Err(e) => println!("spmv_xla_dispatch: skipped ({e})"),
        },
        Err(e) => println!("spmv_xla_dispatch: skipped ({e})"),
    }

    println!("# micro done");
}
