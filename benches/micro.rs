//! Micro-benchmarks of the hot paths (the §Perf profiling surface).
//!
//! `cargo bench --bench micro`
//!
//! Measures, with min-of-N timing: LCA queries, resistance annotation,
//! β-hop neighborhood BFS, tag-store probes, CSR vs XLA SpMV, LDLᵀ
//! factor+solve, the serial vs level-scheduled triangular solve, and
//! the recovery phases. These numbers drive the
//! before/after comparisons recorded in CHANGES.md.
//!
//! Besides the stdout report, the run writes a machine-readable
//! `BENCH_10.json` (override the path with `PDGRASS_BENCH_OUT`): every
//! `report()` sample lands in `bench_ms` and every structural makespan
//! model value in `model_units`. Format documented in ROADMAP.md.
//! `pdgrass benchdiff <old.json> <new.json>` compares two such dumps:
//! `model_units` must match exactly, `bench_ms` within a tolerance band.

use pdgrass::graph::grounded_laplacian;
use pdgrass::recovery::strict::{neighborhoods, TagStore};
use pdgrass::recovery::{self, Params, Strategy};
use pdgrass::solver::{spmv, LdlFactor, SparsifierPrecond};
use pdgrass::tree::{build_spanning, off_tree_edges};
use pdgrass::util::{min_of, Rng};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Wall-clock samples (name, min-of-N ms) accumulated for the JSON dump.
static SAMPLES: Mutex<Vec<(String, f64)>> = Mutex::new(Vec::new());
/// Structural makespan-model values (name, units) — machine-independent.
static MODELS: Mutex<Vec<(String, u64)>> = Mutex::new(Vec::new());

fn report(name: &str, iters: usize, ms: f64, unit_count: u64, unit: &str) {
    let per = ms * 1e6 / unit_count.max(1) as f64;
    println!("{name:<38} {ms:>9.2} ms / {iters} it   ({per:>8.1} ns/{unit})");
    SAMPLES.lock().unwrap().push((name.to_string(), ms));
}

/// Record one structural model value for the JSON dump.
fn model(name: &str, units: u64) {
    MODELS.lock().unwrap().push((name.to_string(), units));
}

/// Write the accumulated samples as `BENCH_10.json` (or
/// `$PDGRASS_BENCH_OUT`). Hand-rolled JSON — names are bench identifiers
/// (no escapes needed), values plain decimals.
fn write_bench_json() {
    let path = std::env::var("PDGRASS_BENCH_OUT").unwrap_or_else(|_| "BENCH_10.json".to_string());
    let mut out = String::from("{\n  \"schema\": \"pdgrass-bench-v1\",\n  \"pr\": 10,\n");
    out.push_str("  \"bench_ms\": {\n");
    let samples = SAMPLES.lock().unwrap();
    for (i, (name, ms)) in samples.iter().enumerate() {
        let sep = if i + 1 == samples.len() { "" } else { "," };
        out.push_str(&format!("    \"{name}\": {ms:.4}{sep}\n"));
    }
    out.push_str("  },\n  \"model_units\": {\n");
    let models = MODELS.lock().unwrap();
    for (i, (name, units)) in models.iter().enumerate() {
        let sep = if i + 1 == models.len() { "" } else { "," };
        out.push_str(&format!("    \"{name}\": {units}{sep}\n"));
    }
    out.push_str("  }\n}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => println!("# could not write {path}: {e}"),
    }
}

/// The pre-pool `par_for`: spawn + join fresh scoped threads on every
/// call. Kept here (only here) as the baseline for the dispatch-cost
/// comparison — the library's `par::par_for` now runs on the persistent
/// pool and must beat this on small-n hot loops.
fn spawn_per_call_for<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Dispatch-overhead comparison: many small parallel loops, the shape of
/// `spmv_par` inside PCG (one small `par_for` per iteration, thousands
/// of iterations per solve).
fn bench_dispatch() {
    let threads = 4usize;
    let calls = 200usize;
    for n in [256usize, 4096] {
        let mut out = vec![0f64; n];
        let grain = (n / (4 * threads)).max(1);
        let (_, ms_spawn) = min_of(5, || {
            for _ in 0..calls {
                let ptr = SendCell(out.as_mut_ptr());
                // SAFETY: each index `i` is visited exactly once, so the
                // writes land on disjoint elements of the live buffer.
                spawn_per_call_for(n, threads, grain, |i| unsafe {
                    *ptr.p().add(i) = (i as f64).sqrt();
                });
            }
        });
        let (_, ms_pool) = min_of(5, || {
            for _ in 0..calls {
                let ptr = SendCell(out.as_mut_ptr());
                // SAFETY: same disjoint-index write pattern as above.
                pdgrass::par::par_for(n, threads, grain, |i| unsafe {
                    *ptr.p().add(i) = (i as f64).sqrt();
                });
            }
        });
        report(&format!("par_for_dispatch_spawn(n={n})"), 5, ms_spawn, calls as u64, "call");
        report(&format!("par_for_dispatch_pool(n={n})"), 5, ms_pool, calls as u64, "call");
        println!(
            "{:<38} pooled dispatch {:.2}x vs spawn-per-call",
            "",
            ms_spawn / ms_pool.max(1e-9)
        );
    }
}

/// Raw-pointer cell for the disjoint-index writes in `bench_dispatch`.
/// Accessed via the method so closures capture the whole cell (edition
/// 2021 disjoint capture would grab the `!Sync` raw pointer field).
struct SendCell(*mut f64);
// SAFETY: the cell wraps a pointer into a buffer that outlives every
// closure, and the bench only performs disjoint-index writes through it.
unsafe impl Send for SendCell {}
// SAFETY: shared use is the same disjoint-index write pattern.
unsafe impl Sync for SendCell {}
impl SendCell {
    fn p(&self) -> *mut f64 {
        self.0
    }
}

/// BLAS-1 serial vs pooled: the ops that dominate a PCG iteration after
/// the SpMV. Pooled dots reduce over the fixed chunk tree; the pooled
/// win should appear at large n while tiny n stays near-serial (the
/// primitives' serial fast paths).
fn bench_blas1() {
    use pdgrass::solver::{axpy, axpy_par, dot, dot_par, norm2, norm2_par};
    let threads = 4usize;
    let calls = 50usize;
    let mut rng = Rng::new(3);
    for n in [4096usize, 1 << 20] {
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f64; n];
        let (_, ms) = min_of(5, || {
            let mut acc = 0.0;
            for _ in 0..calls {
                acc += dot(&a, &b);
            }
            acc
        });
        report(&format!("dot_serial(n={n})"), 5, ms, (calls * n) as u64, "elt");
        let (_, ms_p) = min_of(5, || {
            let mut acc = 0.0;
            for _ in 0..calls {
                acc += dot_par(&a, &b, threads);
            }
            acc
        });
        report(&format!("dot_pooled(n={n})"), 5, ms_p, (calls * n) as u64, "elt");
        let (_, ms) = min_of(5, || {
            let mut acc = 0.0;
            for _ in 0..calls {
                acc += norm2(&a);
            }
            acc
        });
        report(&format!("norm2_serial(n={n})"), 5, ms, (calls * n) as u64, "elt");
        let (_, ms_p) = min_of(5, || {
            let mut acc = 0.0;
            for _ in 0..calls {
                acc += norm2_par(&a, threads);
            }
            acc
        });
        report(&format!("norm2_pooled(n={n})"), 5, ms_p, (calls * n) as u64, "elt");
        let (_, ms) = min_of(5, || {
            for _ in 0..calls {
                axpy(1e-9, &a, &mut y);
            }
        });
        report(&format!("axpy_serial(n={n})"), 5, ms, (calls * n) as u64, "elt");
        let (_, ms_p) = min_of(5, || {
            for _ in 0..calls {
                axpy_par(1e-9, &a, &mut y, threads);
            }
        });
        report(&format!("axpy_pooled(n={n})"), 5, ms_p, (calls * n) as u64, "elt");
    }
}

/// The pre-rewrite clone-based fork–join merge sort, kept here (only
/// here) as the baseline for the sort comparison: it requires
/// `T: Clone`, allocates a full clone of the input up front, and clones
/// every element once per merge level.
mod clone_sort_baseline {
    pub fn par_sort_by<T, F>(v: &mut [T], threads: usize, cmp: &F)
    where
        T: Send + Clone,
        F: Fn(&T, &T) -> std::cmp::Ordering + Sync,
    {
        let threads = threads.max(1);
        if threads == 1 || v.len() < 4096 {
            v.sort_by(cmp);
            return;
        }
        let mut buf = v.to_vec();
        let depth = (threads as f64).log2().ceil() as usize;
        msort(v, &mut buf, cmp, depth);
    }

    fn msort<T, F>(v: &mut [T], buf: &mut [T], cmp: &F, depth: usize)
    where
        T: Send + Clone,
        F: Fn(&T, &T) -> std::cmp::Ordering + Sync,
    {
        if depth == 0 || v.len() < 4096 {
            v.sort_by(cmp);
            return;
        }
        let mid = v.len() / 2;
        let (vl, vr) = v.split_at_mut(mid);
        let (bl, br) = buf.split_at_mut(mid);
        pdgrass::par::ThreadPool::global()
            .join(|| msort(vl, bl, cmp, depth - 1), || msort(vr, br, cmp, depth - 1));
        merge(vl, vr, buf, cmp);
        v.clone_from_slice(buf);
    }

    fn merge<T, F>(a: &[T], b: &[T], out: &mut [T], cmp: &F)
    where
        T: Clone,
        F: Fn(&T, &T) -> std::cmp::Ordering,
    {
        let (mut i, mut j, mut k) = (0, 0, 0);
        while i < a.len() && j < b.len() {
            if cmp(&a[i], &b[j]) != std::cmp::Ordering::Greater {
                out[k] = a[i].clone();
                i += 1;
            } else {
                out[k] = b[j].clone();
                j += 1;
            }
            k += 1;
        }
        while i < a.len() {
            out[k] = a[i].clone();
            i += 1;
            k += 1;
        }
        while j < b.len() {
            out[k] = b[j].clone();
            j += 1;
            k += 1;
        }
    }
}

/// Old clone-per-merge sort vs the new move-based ping-pong sort, on an
/// `OffTreeEdge`-shaped 48-byte payload (the recovery step-2 workload).
fn bench_sort() {
    #[derive(Clone)]
    struct FatEdge {
        _eid: u32,
        _u: u32,
        _v: u32,
        _lca: u32,
        _w: f64,
        _resistance: f64,
        score: f64,
        _pad: f64,
    }
    let threads = 4usize;
    let n = 400_000usize;
    let mk = |rng: &mut Rng| -> Vec<FatEdge> {
        (0..n)
            .map(|i| FatEdge {
                _eid: i as u32,
                _u: 0,
                _v: 1,
                _lca: 0,
                _w: 1.0,
                _resistance: 0.0,
                score: rng.next_f64(),
                _pad: 0.0,
            })
            .collect()
    };
    let cmp = |a: &FatEdge, b: &FatEdge| {
        b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal)
    };
    let (_, ms_old) = min_of(5, || {
        let mut v = mk(&mut Rng::new(4));
        clone_sort_baseline::par_sort_by(&mut v, threads, &cmp);
        v.len()
    });
    report(&format!("sort_clone_based(n={n})"), 5, ms_old, n as u64, "elt");
    let (_, ms_new) = min_of(5, || {
        let mut v = mk(&mut Rng::new(4));
        pdgrass::par::sort::par_sort_by(&mut v, threads, &cmp);
        v.len()
    });
    report(&format!("sort_move_based(n={n})"), 5, ms_new, n as u64, "elt");
    println!(
        "{:<38} move-based sort {:.2}x vs clone-based",
        "",
        ms_old / ms_new.max(1e-9)
    );
}

/// α-sweep cost: recompute steps 1–4 per α (what the experiment drivers
/// did before the session API) vs one shared `Prepared` that pays steps
/// 1–3 once and re-runs only step 4 per α. Documents the sweep speedup
/// the prepare-once/recover-many split buys.
fn bench_alpha_sweep() {
    use pdgrass::{RecoverOpts, Sparsify};
    let (name, scale, seed) = ("07-com-DBLP", 0.3, 42u64);
    let alphas = [0.02, 0.05, 0.10];
    let (_, ms_fresh) = min_of(3, || {
        let mut total = 0usize;
        for &alpha in &alphas {
            let g = pdgrass::gen::suite::build(name, scale, seed);
            let sp = build_spanning(&g);
            total += recovery::pdgrass(&g, &sp, &Params::new(alpha, 4)).edges.len();
        }
        total
    });
    report("alpha_sweep_recompute_per_alpha", 3, ms_fresh, alphas.len() as u64, "alpha");
    let (_, ms_shared) = min_of(3, || {
        let prepared = Sparsify::suite(name, scale, seed).unwrap().prepare().unwrap();
        let mut total = 0usize;
        for &alpha in &alphas {
            total += prepared
                .recover(&RecoverOpts::with_threads(alpha, 4))
                .unwrap()
                .edges()
                .len();
        }
        total
    });
    report("alpha_sweep_shared_prepared", 3, ms_shared, alphas.len() as u64, "alpha");
    println!(
        "{:<38} shared Prepared {:.2}x vs recompute-per-alpha",
        "",
        ms_fresh / ms_shared.max(1e-9)
    );
}

/// Prepare pipeline: barrier stage-sum vs the streamed overlap, on a
/// suite graph. Wall-clock on this 1-core container is informational;
/// the structural assertion replays `schedsim`'s overlap model on the
/// measured off-tree size: serially the streamed makespan must equal the
/// barrier stage-sum exactly (streaming costs nothing at one thread),
/// and at 8 simulated threads the overlap must win once chunks
/// outnumber workers.
fn bench_prepare_pipeline() {
    use pdgrass::coordinator::schedsim::{prep_barrier_makespan, prep_streamed_makespan, PrepSim};
    use pdgrass::Sparsify;
    let (name, scale, seed) = ("07-com-DBLP", 0.3, 42u64);
    let (off_n, ms_barrier) = min_of(3, || {
        Sparsify::suite(name, scale, seed).unwrap().threads(4).prepare().unwrap().num_off_tree()
    });
    report("prepare_barrier", 3, ms_barrier, off_n as u64, "edge");
    let (_, ms_streamed) = min_of(3, || {
        Sparsify::suite(name, scale, seed)
            .unwrap()
            .threads(4)
            .prepare_streamed()
            .unwrap()
            .num_off_tree()
    });
    report("prepare_streamed", 3, ms_streamed, off_n as u64, "edge");
    println!(
        "{:<38} streamed prepare {:.2}x vs barrier (wall, 1-core box)",
        "",
        ms_barrier / ms_streamed.max(1e-9)
    );
    let sim = PrepSim::uniform(off_n, pdgrass::recovery::score::SCORE_CHUNK);
    let (b1, s1) = (prep_barrier_makespan(&sim, 1), prep_streamed_makespan(&sim, 1));
    assert!(s1 <= b1, "streamed must be no worse serially: {s1} > {b1}");
    let (b8, s8) = (prep_barrier_makespan(&sim, 8), prep_streamed_makespan(&sim, 8));
    model("prep_makespan_barrier_1t", b1);
    model("prep_makespan_streamed_1t", s1);
    model("prep_makespan_barrier_8t", b8);
    model("prep_makespan_streamed_8t", s8);
    println!(
        "{:<38} makespan model: 1t {} vs {} units, 8t barrier {} vs streamed {} ({:.2}x)",
        "",
        b1,
        s1,
        b8,
        s8,
        b8 as f64 / s8.max(1) as f64
    );
    assert!(s8 <= b8, "streamed makespan must never exceed the barrier sum");
    if sim.chunk_units.len() > 8 {
        assert!(s8 < b8, "overlap must win at 8 threads: streamed {s8} !< barrier {b8}");
    }
}

/// Giant-subtask worst case (the feGRASS pathology, §V): a star-like hub
/// concentrates off-tree edges in one dominant LCA subtask, so Outer
/// degrades to a single worker grinding the subtask serially. Sharded
/// splits it into shards that speculate concurrently. Wall-clock numbers
/// are informational on a 1-core container; the structural comparison —
/// the same work–span replay the scaling figures use — is the
/// per-strategy makespan at 8 simulated threads, where Sharded must beat
/// Outer (which by definition sits at 1x on a single giant subtask).
fn bench_giant_subtask() {
    use pdgrass::coordinator::schedsim;
    let g = pdgrass::gen::hub_graph(30_000, 1, 20_000, &mut Rng::new(21));
    let sp = build_spanning(&g);
    let base = Params { cutoff_edges: 1000, shard_min: 512, ..Params::new(0.10, 8) };
    let outer = Params { strategy: Strategy::Outer, ..base };
    let sharded = Params { strategy: Strategy::Sharded, ..base };
    let off_n = pdgrass::tree::off_tree_edges(&g, &sp).len() as u64;
    let (_, ms_outer) = min_of(3, || recovery::pdgrass(&g, &sp, &outer).edges.len());
    report("giant_subtask_outer", 3, ms_outer, off_n, "edge");
    let (_, ms_sharded) = min_of(3, || recovery::pdgrass(&g, &sp, &sharded).edges.len());
    report("giant_subtask_sharded", 3, ms_sharded, off_n, "edge");
    // Structural makespan at 8 threads, each strategy replayed from its
    // own measured cost trace (a Sharded trace charges wasted speculative
    // explores that Outer never pays, so Outer gets its own trace).
    let biggest = |r: &recovery::Recovery| -> Vec<(u32, u32)> {
        r.trace
            .as_ref()
            .expect("trace requested")
            .subtask_costs
            .iter()
            .max_by_key(|c| c.len())
            .expect("hub graph must yield subtasks")
            .clone()
    };
    let outer_costs = biggest(&recovery::pdgrass::pdgrass_traced(&g, &sp, &outer, true));
    let costs = biggest(&recovery::pdgrass::pdgrass_traced(&g, &sp, &sharded, true));
    // Outer hands the whole subtask to one worker: makespan == its
    // serial units, at any thread count.
    let outer_units: u64 = outer_costs.iter().map(|&(c, e)| c as u64 + e as u64).sum();
    let (s, par) = schedsim::simulate_sharded(&costs, &schedsim::SimParams::sharded(8, 512));
    let sharded_units = s + par;
    model("giant_subtask_makespan_outer_8t", outer_units);
    model("giant_subtask_makespan_sharded_8t", sharded_units);
    println!(
        "{:<38} makespan(8t) outer {} units vs sharded {} units — sharded {:.2}x",
        "",
        outer_units,
        sharded_units,
        outer_units as f64 / sharded_units.max(1) as f64
    );
    assert!(
        sharded_units < outer_units,
        "sharded must beat outer on the giant subtask at 8 threads"
    );
}

/// Cold prepare vs snapshot warm start: what the serve layer's
/// `snapshot_dir` buys per cache miss. Cold pays steps 1–3 in full;
/// warm pays encode-once then decode+validate per restart. The decoded
/// state must re-encode to the identical bytes (asserted every run).
fn bench_snapshot() {
    use pdgrass::{Prepared, Sparsify};
    let (name, scale, seed) = ("07-com-DBLP", 0.3, 42u64);
    let (prepared, ms_cold) =
        min_of(3, || Sparsify::suite(name, scale, seed).unwrap().threads(4).prepare().unwrap());
    let off_n = prepared.num_off_tree() as u64;
    report("snapshot_cold_prepare", 3, ms_cold, off_n, "edge");
    let (bytes, ms_enc) = min_of(3, || prepared.to_snapshot_bytes());
    report("snapshot_encode", 3, ms_enc, bytes.len() as u64, "byte");
    let (loaded, ms_dec) = min_of(3, || Prepared::from_snapshot_bytes(&bytes).unwrap());
    report("snapshot_decode_validate", 3, ms_dec, bytes.len() as u64, "byte");
    assert_eq!(loaded.to_snapshot_bytes(), bytes, "round trip must be bitwise stable");
    println!(
        "{:<38} warm load {:.2}x vs cold prepare ({} KiB container)",
        "",
        ms_cold / ms_dec.max(1e-9),
        bytes.len() / 1024
    );
}

/// Cache-blocked nnz-balanced SpMV vs the row-count split, on a hub-star
/// Laplacian whose heavy rows defeat a per-row-count partition (one
/// chunk inherits the hub rows and serializes the sweep). Wall clock on
/// this 1-core container is informational; the structural assertion
/// replays [`spmv_traffic_model`]: at 8 threads the nnz-balanced blocked
/// partition must beat the row-count split. Bitwise equality of the
/// parallel kernel against the serial sweep is asserted on every run.
fn bench_spmv_blocked() {
    use pdgrass::solver::{spmv_par, spmv_traffic_model};
    let g = pdgrass::gen::hub_graph(40_000, 2, 20_000, &mut Rng::new(23));
    let a = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(24);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let mut y = vec![0.0; a.n];
    let (_, ms_serial) = min_of(10, || spmv(&a, &x, &mut y));
    report("spmv_hub_serial", 10, ms_serial, a.nnz() as u64, "nnz");
    let serial = y.clone();
    let (_, ms_par) = min_of(10, || spmv_par(&a, &x, &mut y, 8));
    report("spmv_hub_blocked(8t)", 10, ms_par, a.nnz() as u64, "nnz");
    for (i, (got, want)) in y.iter().zip(&serial).enumerate() {
        assert_eq!(got.to_bits(), want.to_bits(), "blocked spmv diverged at row {i}");
    }
    let (row_count, balanced) = spmv_traffic_model(&a, 8);
    model("spmv_traffic_row_count_8t", row_count);
    model("spmv_traffic_balanced_blocked_8t", balanced);
    println!(
        "{:<38} traffic model(8t): row-count {} units vs balanced blocked {} ({:.2}x)",
        "",
        row_count,
        balanced,
        row_count as f64 / balanced.max(1) as f64
    );
    assert!(
        balanced < row_count,
        "balanced blocked partition must beat the row-count split on the hub star: \
         {balanced} !< {row_count}"
    );
}

/// Serial vs level-scheduled triangular solve, on a grid-sparsifier
/// factor (the PCG preconditioner workload). Wall clock on this 1-core
/// container is informational; the structural assertion replays the
/// factor's own makespan model: at 1 thread the levelled schedule costs
/// exactly the serial sweep, and at 8 threads the level sets must
/// shorten the critical path. Bitwise equality of the two solves is
/// asserted on every run.
fn bench_trisolve() {
    let g = pdgrass::gen::grid(200, 200, 0.4, &mut Rng::new(17));
    let sp = build_spanning(&g);
    let r = recovery::pdgrass(&g, &sp, &Params::new(0.05, 4));
    let p = recovery::sparsifier(&g, &sp, &r.edges);
    let lp = grounded_laplacian(&p, 0);
    let perm = pdgrass::solver::rcm(&lp);
    let lpp = pdgrass::solver::permute_sym(&lp, &perm);
    let f = LdlFactor::factor(&lpp).unwrap();
    let mut rng = Rng::new(18);
    let b: Vec<f64> = (0..lpp.n).map(|_| rng.normal()).collect();
    let mut z = b.clone();
    let (_, ms_serial) = min_of(10, || {
        z.copy_from_slice(&b);
        f.solve(&mut z);
    });
    report("trisolve_serial", 10, ms_serial, f.nnz_l() as u64, "nnz");
    let serial = z.clone();
    let (_, ms_par) = min_of(10, || {
        z.copy_from_slice(&b);
        f.solve_par(&mut z, 8);
    });
    report("trisolve_levelled(8t)", 10, ms_par, f.nnz_l() as u64, "nnz");
    for (i, (got, want)) in z.iter().zip(&serial).enumerate() {
        assert_eq!(got.to_bits(), want.to_bits(), "levelled solve diverged at row {i}");
    }
    let sched = f.schedule();
    println!(
        "{:<38} schedule: {} forward / {} backward levels over n={}",
        "",
        sched.num_forward_levels(),
        sched.num_backward_levels(),
        lpp.n
    );
    let (s1, l1) = f.solve_makespan_model(1);
    assert_eq!(s1, l1, "levelled schedule must cost the serial sweep at 1 thread");
    let (s8, l8) = f.solve_makespan_model(8);
    model("trisolve_makespan_serial_1t", s1);
    model("trisolve_makespan_serial_8t", s8);
    model("trisolve_makespan_levelled_8t", l8);
    println!(
        "{:<38} makespan model: 1t {} units, 8t serial {} vs levelled {} ({:.2}x)",
        "",
        s1,
        s8,
        l8,
        s8 as f64 / l8.max(1) as f64
    );
    assert!(
        l8 < s8,
        "level scheduling must shorten the critical path at 8 threads: {l8} !< {s8}"
    );
}

fn main() {
    println!("# micro bench: prepare pipeline, barrier stage-sum vs streamed overlap");
    bench_prepare_pipeline();
    println!("# micro bench: giant-subtask recovery, Outer vs Sharded (star-graph worst case)");
    bench_giant_subtask();
    println!("# micro bench: alpha-sweep with shared Prepared vs recompute (session API)");
    bench_alpha_sweep();
    println!("# micro bench: cold prepare vs snapshot encode/decode warm start");
    bench_snapshot();
    println!("# micro bench: parallel-substrate dispatch cost (spawn vs persistent pool)");
    bench_dispatch();
    println!("# micro bench: BLAS-1 serial vs pooled (PCG inner-loop ops)");
    bench_blas1();
    println!("# micro bench: clone-based vs move-based parallel sort");
    bench_sort();
    println!("# micro bench: serial vs level-scheduled triangular solve (PCG preconditioner)");
    bench_trisolve();
    println!("# micro bench: cache-blocked nnz-balanced SpMV vs row-count split (hub star)");
    bench_spmv_blocked();

    let g = pdgrass::gen::suite::build("15-M6", 0.5, 42);
    println!("# micro bench on 15-M6@0.5: |V|={} |E|={}", g.num_vertices(), g.num_edges());
    let sp = build_spanning(&g);

    // LCA queries
    let off_ids: Vec<(u32, u32)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
    let (_, ms) = min_of(5, || {
        let mut acc = 0u64;
        for &(u, v) in &off_ids {
            acc = acc.wrapping_add(sp.skip.lca(u, v) as u64);
        }
        acc
    });
    report("lca_query", 5, ms, off_ids.len() as u64, "query");

    // Resistance annotation (step 1)
    let (off, ms) = min_of(5, || off_tree_edges(&g, &sp));
    report("off_tree_annotation", 5, ms, off.len() as u64, "edge");

    // Neighborhood BFS at the recovery's β*
    let sample: Vec<_> = off.iter().take(20_000).collect();
    let (units, ms) = min_of(5, || {
        let mut acc = 0u64;
        for e in &sample {
            let (_, _, c) = neighborhoods(&sp, e, 8);
            acc += c as u64;
        }
        acc
    });
    report("neighborhood_bfs(beta*<=8)", 5, ms, units, "visit");

    // Tag-store probe throughput
    let mut ts = TagStore::new();
    let mut rng = Rng::new(1);
    for k in 0..2000u32 {
        let su: Vec<u32> = (0..8).map(|_| rng.next_u32() % 100_000).collect();
        let sv: Vec<u32> = (0..8).map(|_| rng.next_u32() % 100_000).collect();
        ts.add(k, &su, &sv);
    }
    let probes: Vec<(u32, u32)> =
        (0..200_000).map(|_| (rng.next_u32() % 100_000, rng.next_u32() % 100_000)).collect();
    let (_, ms) = min_of(5, || {
        let mut cost = 0u32;
        let mut hits = 0u64;
        for &(u, v) in &probes {
            if ts.is_similar(u, v, &mut cost) {
                hits += 1;
            }
        }
        hits
    });
    report("tagstore_probe", 5, ms, probes.len() as u64, "probe");

    // Recovery end to end (serial vs mixed)
    for (label, strat) in [("recovery_serial", Strategy::Serial), ("recovery_mixed", Strategy::Mixed)] {
        let params = Params { strategy: strat, cutoff_edges: 10_000, ..Params::new(0.05, 4) };
        let (_, ms) = min_of(3, || recovery::pdgrass(&g, &sp, &params));
        report(label, 3, ms, off.len() as u64, "edge");
    }

    // SpMV: CSR f64 (serial + 4-thread "parallel" on this 1-core box)
    let a = grounded_laplacian(&g, 0);
    let mut rng = Rng::new(2);
    let x: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
    let mut y = vec![0.0; a.n];
    let (_, ms) = min_of(10, || spmv(&a, &x, &mut y));
    report("spmv_csr_f64", 10, ms, a.nnz() as u64, "nnz");

    // LDL factor + solve on a sparsifier
    let r = recovery::pdgrass(&g, &sp, &Params::new(0.05, 1));
    let p = recovery::sparsifier(&g, &sp, &r.edges);
    let lp = grounded_laplacian(&p, 0);
    let (m, ms) = min_of(3, || SparsifierPrecond::from_matrix(&lp).unwrap());
    report("ldl_factor(rcm)", 3, ms, lp.nnz() as u64, "nnz");
    println!("{:<38} fill nnz(L) = {}", "", m.nnz_l());
    let ap = pdgrass::solver::rcm(&lp);
    let lp_p = pdgrass::solver::permute_sym(&lp, &ap);
    let f = LdlFactor::factor(&lp_p).unwrap();
    let mut z = x[..lp.n].to_vec();
    let (_, ms) = min_of(10, || {
        z.copy_from_slice(&x[..lp.n]);
        f.solve(&mut z);
    });
    report("ldl_solve", 10, ms, f.nnz_l() as u64, "nnz");

    // XLA SpMV dispatch (if artifacts are present)
    match pdgrass::runtime::Runtime::open_default() {
        Ok(rt) => match pdgrass::runtime::prepare_spmv(&rt, &a) {
            Ok(xs) => {
                let mut yx = vec![0.0; a.n];
                let (_, ms) = min_of(10, || xs.apply(&x, &mut yx).unwrap());
                report("spmv_xla_dispatch", 10, ms, a.nnz() as u64, "nnz");
                println!(
                    "{:<38} bucket n={} k={} pad={:.0}% tail={}",
                    "",
                    xs.ell.n_bucket,
                    xs.ell.k,
                    100.0 * xs.ell.padding_ratio(),
                    xs.ell.tail.len()
                );
            }
            Err(e) => println!("spmv_xla_dispatch: skipped ({e})"),
        },
        Err(e) => println!("spmv_xla_dispatch: skipped ({e})"),
    }

    write_bench_json();
    println!("# micro done");
}
