//! Regenerates **Table III**: Judge-before-Parallel statistics on the
//! com-Youtube analogue (biggest-subtask blocked-execution counters).
//!
//! `cargo bench --bench table3_jbp`

use pdgrass::coordinator::{experiments, PipelineConfig};

fn main() {
    let scale: f64 = std::env::var("PDGRASS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PipelineConfig { scale, alpha: 0.02, ..Default::default() };
    println!("# Table III bench — Judge-before-Parallel on 09-com-Youtube (scale={scale})");
    let (without, with) = experiments::table3(&cfg);
    // Paper shape: JBP removes all parallel-region skips and cuts false
    // positives; every blocked edge explores.
    assert_eq!(with.skipped_in_parallel, 0);
    assert!(without.skipped_in_parallel > 0);
    assert_eq!(with.edges_in_blocks, with.explored_in_parallel);
    assert!(with.false_positives <= without.false_positives);
    println!("\n# table3_jbp done");
}
